"""Accumulating Automata (AA) string matching on secret-shares (paper §3.1).

The automaton of Table 3 matches a length-``x`` pattern against a word by
chaining per-position one-hot inner products:

    v_j      = Σ_α SS[j, α] · p'[j, α]          (share-space, degree 2t)
    N_{j+1}  = N_j · v_j                         (degree accumulates)

``N_{x+1}`` is a share of 1 iff the word equals the pattern. Because padded
positions hold the terminator one-hot, equality is exact-word (the paper's
"John " fix). Everything here is per-cloud local — no cross-share traffic.

Two implementations, selected through the backend registry
(``repro.api.backends``):
  * ``backend="jnp"``    — reference, pure jnp (this file),
  * ``backend="pallas"`` — fused VMEM-tiled kernel (repro.kernels.ops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import field
from .shamir import Shares

__all__ = ["match_words", "match_column", "count_column", "match_matrix",
           "slide_windows", "match_suffix", "window_count",
           "equality_indicator", "zero_indicator"]


def _inner_over_alphabet(col_vals: jax.Array, pat_vals: jax.Array) -> jax.Array:
    """v[..., j] = Σ_α col[..., j, α] · pat[..., j, α]  (mod p)."""
    return field.dot(col_vals, pat_vals, axis=-1)


def _chain(v: jax.Array) -> jax.Array:
    """N_{x+1} = Π_j v[..., j] via sequential chain (Table 3 order)."""
    w = v.shape[-1]
    acc = v[..., 0]
    for j in range(1, w):          # w is static & small; unrolled chain
        acc = field.mul(acc, v[..., j])
    return acc


def match_words(column: Shares, pattern: Shares) -> Shares:
    """Match pattern (c, W, A) against every word of column (c, n, W, A).

    Returns Shares (c, n): share of 1 where the word equals the pattern.
    Degree: 2·t·W for degree-t inputs.
    """
    col = column.values                            # (c, n, W, A)
    pat = pattern.values[:, None]                  # (c, 1, W, A)
    v = _inner_over_alphabet(col, jnp.broadcast_to(pat, col.shape))
    out_degree = (column.degree + pattern.degree) * col.shape[-2]
    return Shares(_chain(v), out_degree)


# alias used by query code: a "column" is (c, n, W, A)
match_column = match_words


def count_column(column: Shares, pattern: Shares) -> Shares:
    """§3.1 count: accumulate the AA output over all tuples.

    Faithful to Table 3's final accumulation step
    ``N_{x+1} += N_x · v_x`` across iterations: the per-tuple match bits are
    summed in share space, so the cloud never sees the count.
    """
    return match_words(column, pattern).sum(axis=0)


def match_matrix(col_x: Shares, col_y: Shares, *,
                 method: str = "chain") -> Shares:
    """All-pairs word match between two shared columns (join inner loop).

    col_x: (c, n_x, W, A), col_y: (c, n_y, W, A)
    Returns Shares (c, n_x, n_y) — share of 1 where word_i == word_j.

    method="chain" (paper-faithful, Table 3): per position a mod-p matmul
    over the alphabet axis, chained multiplicatively — W dot-sets.

    method="aggregate" (beyond-paper, §Perf): ONE dot over the flattened
    (W·A) axis gives P = #matching positions ∈ {0..W} (as a share); the
    equality indicator is the Lagrange basis polynomial
    ``1[P==W] = (Π_{j<W} (P−j)) / W!`` evaluated share-side — same output,
    same final degree (2tW), but 1 dot-set instead of W and a fusable
    elementwise chain (measured 12× fewer mod-p dots on the paper_db cell).
    """
    xv = col_x.values            # (c, nx, W, A)
    yv = col_y.values            # (c, ny, W, A)
    w = xv.shape[-2]
    out_degree = (col_x.degree + col_y.degree) * w
    if method == "aggregate":
        c, nx = xv.shape[0], xv.shape[1]
        ny = yv.shape[1]
        xf = xv.reshape(c, nx, -1)
        yf = yv.reshape(c, ny, -1)
        p_cnt = field.matmul(xf, jnp.swapaxes(yf, -1, -2))   # (c,nx,ny)
        return Shares(_equality_indicator(p_cnt, w), out_degree)
    acc = None
    for j in range(w):
        pj = field.matmul(xv[:, :, j, :], jnp.swapaxes(yv[:, :, j, :], -1, -2))
        acc = pj if acc is None else field.mul(acc, pj)
    return Shares(acc, out_degree)


def _equality_indicator(p_cnt, w: int):
    """1[P == w] = Π_{j=0}^{w-1} (P − j) · (w!)⁻¹   (mod p)."""
    acc = None
    for j in range(w):
        term = field.sub(p_cnt, jnp.asarray(j, field.DTYPE))
        acc = term if acc is None else field.mul(acc, term)
    inv_wfact = _inv_factorial(w)
    return field.mul(acc, jnp.asarray(inv_wfact, field.DTYPE))


#: public raw-array form (shared with the backend registry's batched
#: aggregate match-matrix path). Input: P shares, static w; degree ×w.
equality_indicator = _equality_indicator


def zero_indicator(p_cnt, m: int):
    """1[P == 0] = Π_{j=1}^{m} (j − P) · (m!)⁻¹  over the domain {0..m}.

    The Lagrange basis polynomial at 0: a share-local (cloud-side)
    elementwise chain, degree ×m. Used by the CONTAINS matcher, whose
    window count P ∈ {0..M} may exceed 1 (repeated substrings)."""
    acc = None
    for j in range(1, m + 1):
        term = field.sub(jnp.asarray(j, field.DTYPE), p_cnt)
        acc = term if acc is None else field.mul(acc, term)
    return field.mul(acc, jnp.asarray(_inv_factorial(m), field.DTYPE))


# ---------------------------------------------------------------------------
# Sliding-window automata step (§3.1 general patterns)
# ---------------------------------------------------------------------------

def slide_windows(column: Shares, pattern: Shares) -> Shares:
    """Chain a k-position pattern tile at every window offset.

    column (c, n, W, A) × pattern (c, k, A) -> Shares (c, n, M) with
    M = W − k + 1: windows[..., o] is a share of 1 iff the word's
    characters at positions o..o+k−1 equal the tile. Degree (tc+tp)·k.
    Reference semantics of the ``aa_slide_batch`` backend op.
    """
    col = column.values                                  # (c, n, W, A)
    pat = pattern.values                                 # (c, k, A)
    k = pat.shape[-2]
    w = col.shape[-2]
    m = w - k + 1
    idx = jnp.arange(m)[:, None] + jnp.arange(k)[None, :]
    win = col[:, :, idx, :]                              # (c, n, M, k, A)
    v = field.dot(win, pat[:, None, None], axis=-1)      # (c, n, M, k)
    return Shares(_chain(v), (column.degree + pattern.degree) * k)


def match_suffix(column: Shares, pattern: Shares) -> Shares:
    """Suffix match bit: Σ_o windows[o] · term[o+k]  (term[W] ≡ 1).

    For a wildcard-free tile the windows are mutually exclusive (the tile's
    real characters cannot match padding, so a matching window must end
    exactly where the terminator run starts), hence the linear sum is the
    exact 0/1 match bit. Returns Shares (c, n), degree (tc+tp)·k + tc
    (the terminator factor; M = 1 skips it).
    """
    win = slide_windows(column, pattern)                 # (c, n, M)
    col = column.values
    k = pattern.values.shape[-2]
    m = col.shape[-2] - k + 1
    if m == 1:
        return Shares(win.values[..., 0], win.degree)
    term = col[:, :, k:, 0]                              # (c, n, M-1)
    ones = jnp.ones(term.shape[:-1] + (1,), field.DTYPE)
    termext = jnp.concatenate([term, ones], axis=-1)     # (c, n, M)
    bits = field.sum_(field.mul(win.values, termext), axis=-1)
    return Shares(bits, win.degree + column.degree)


def window_count(column: Shares, pattern: Shares) -> Shares:
    """P = Σ_o windows[o] — the CONTAINS window count (c, n), ∈ {0..M}
    secret-side for wildcard-free tiles. The match bit is
    ``1 − zero_indicator(P, M)`` after a degree-reduction re-share."""
    win = slide_windows(column, pattern)
    return Shares(field.sum_(win.values, axis=-1), win.degree)


def _inv_factorial(w: int) -> int:
    p = int(field.P)
    f = 1
    for j in range(2, w + 1):
        f = (f * j) % p
    return pow(f, p - 2, p)
