"""Accumulating Automata (AA) string matching on secret-shares (paper §3.1).

The automaton of Table 3 matches a length-``x`` pattern against a word by
chaining per-position one-hot inner products:

    v_j      = Σ_α SS[j, α] · p'[j, α]          (share-space, degree 2t)
    N_{j+1}  = N_j · v_j                         (degree accumulates)

``N_{x+1}`` is a share of 1 iff the word equals the pattern. Because padded
positions hold the terminator one-hot, equality is exact-word (the paper's
"John " fix). Everything here is per-cloud local — no cross-share traffic.

Two implementations, selected through the backend registry
(``repro.api.backends``):
  * ``backend="jnp"``    — reference, pure jnp (this file),
  * ``backend="pallas"`` — fused VMEM-tiled kernel (repro.kernels.ops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import field
from .shamir import Shares

__all__ = ["match_words", "match_column", "count_column", "match_matrix"]


def _inner_over_alphabet(col_vals: jax.Array, pat_vals: jax.Array) -> jax.Array:
    """v[..., j] = Σ_α col[..., j, α] · pat[..., j, α]  (mod p)."""
    return field.dot(col_vals, pat_vals, axis=-1)


def _chain(v: jax.Array) -> jax.Array:
    """N_{x+1} = Π_j v[..., j] via sequential chain (Table 3 order)."""
    w = v.shape[-1]
    acc = v[..., 0]
    for j in range(1, w):          # w is static & small; unrolled chain
        acc = field.mul(acc, v[..., j])
    return acc


def match_words(column: Shares, pattern: Shares) -> Shares:
    """Match pattern (c, W, A) against every word of column (c, n, W, A).

    Returns Shares (c, n): share of 1 where the word equals the pattern.
    Degree: 2·t·W for degree-t inputs.
    """
    col = column.values                            # (c, n, W, A)
    pat = pattern.values[:, None]                  # (c, 1, W, A)
    v = _inner_over_alphabet(col, jnp.broadcast_to(pat, col.shape))
    out_degree = (column.degree + pattern.degree) * col.shape[-2]
    return Shares(_chain(v), out_degree)


# alias used by query code: a "column" is (c, n, W, A)
match_column = match_words


def count_column(column: Shares, pattern: Shares) -> Shares:
    """§3.1 count: accumulate the AA output over all tuples.

    Faithful to Table 3's final accumulation step
    ``N_{x+1} += N_x · v_x`` across iterations: the per-tuple match bits are
    summed in share space, so the cloud never sees the count.
    """
    return match_words(column, pattern).sum(axis=0)


def match_matrix(col_x: Shares, col_y: Shares, *,
                 method: str = "chain") -> Shares:
    """All-pairs word match between two shared columns (join inner loop).

    col_x: (c, n_x, W, A), col_y: (c, n_y, W, A)
    Returns Shares (c, n_x, n_y) — share of 1 where word_i == word_j.

    method="chain" (paper-faithful, Table 3): per position a mod-p matmul
    over the alphabet axis, chained multiplicatively — W dot-sets.

    method="aggregate" (beyond-paper, §Perf): ONE dot over the flattened
    (W·A) axis gives P = #matching positions ∈ {0..W} (as a share); the
    equality indicator is the Lagrange basis polynomial
    ``1[P==W] = (Π_{j<W} (P−j)) / W!`` evaluated share-side — same output,
    same final degree (2tW), but 1 dot-set instead of W and a fusable
    elementwise chain (measured 12× fewer mod-p dots on the paper_db cell).
    """
    xv = col_x.values            # (c, nx, W, A)
    yv = col_y.values            # (c, ny, W, A)
    w = xv.shape[-2]
    out_degree = (col_x.degree + col_y.degree) * w
    if method == "aggregate":
        c, nx = xv.shape[0], xv.shape[1]
        ny = yv.shape[1]
        xf = xv.reshape(c, nx, -1)
        yf = yv.reshape(c, ny, -1)
        p_cnt = field.matmul(xf, jnp.swapaxes(yf, -1, -2))   # (c,nx,ny)
        return Shares(_equality_indicator(p_cnt, w), out_degree)
    acc = None
    for j in range(w):
        pj = field.matmul(xv[:, :, j, :], jnp.swapaxes(yv[:, :, j, :], -1, -2))
        acc = pj if acc is None else field.mul(acc, pj)
    return Shares(acc, out_degree)


def _equality_indicator(p_cnt, w: int):
    """1[P == w] = Π_{j=0}^{w-1} (P − j) · (w!)⁻¹   (mod p)."""
    acc = None
    for j in range(w):
        term = field.sub(p_cnt, jnp.asarray(j, field.DTYPE))
        acc = term if acc is None else field.mul(acc, term)
    inv_wfact = _inv_factorial(w)
    return field.mul(acc, jnp.asarray(inv_wfact, field.DTYPE))


def _inv_factorial(w: int) -> int:
    p = int(field.P)
    f = 1
    for j in range(2, w + 1):
        f = (f * j) % p
    return pow(f, p - 2, p)
