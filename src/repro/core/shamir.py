"""Shamir secret-sharing over F_p (Mersenne-31) — vectorized, degree-tracked.

A secret ``s`` is hidden in a random degree-``t`` polynomial ``q`` with
``q(0) = s``; cloud ``k`` receives ``q(x_k)`` with distinct public evaluation
points ``x_k = k+1``. Every value of a secret-shared tensor uses an
*independent* polynomial (fresh randomness), which is the paper's defence
against frequency-count attacks (§2.1).

Share-space computation (the whole point of the paper):
  * ``shares(a) + shares(b)`` elementwise per cloud  -> shares of ``a+b``
    (degree unchanged),
  * ``shares(a) * shares(b)`` elementwise per cloud  -> shares of ``a*b``
    (degree adds),
so queries run obliviously at the clouds. ``Shares`` tracks the polynomial
degree statically; interpolation asserts ``n_shares >= degree+1``.

Degree reduction (§3.4 / [32]) is implemented honestly as a re-sharing
protocol round: each cloud re-shares its share with a fresh degree-``t``
polynomial and the new shares are combined with Lagrange weights. This is the
only operation that communicates across the cloud axis, and it is an explicit,
counted protocol round (see ``core.costs``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import field
from .field import P, DTYPE


# ---------------------------------------------------------------------------
# Shares pytree
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Shares:
    """Secret-shared tensor. ``values[k]`` lives at cloud ``k``.

    values: uint32[c, ...]  — axis 0 is the cloud/share axis.
    degree: static int      — polynomial degree of the sharing.
    """
    values: jax.Array
    degree: int = dataclasses.field(metadata=dict(static=True), default=1)

    @property
    def n_shares(self) -> int:
        return self.values.shape[0]

    @property
    def shape(self):
        return self.values.shape[1:]

    # -- share-space arithmetic (runs *per cloud*, no cross-cloud traffic) --
    def __add__(self, other: "Shares") -> "Shares":
        _check_compat(self, other)
        return Shares(field.add(self.values, other.values),
                      max(self.degree, other.degree))

    def __sub__(self, other: "Shares") -> "Shares":
        _check_compat(self, other)
        return Shares(field.sub(self.values, other.values),
                      max(self.degree, other.degree))

    def __mul__(self, other: "Shares") -> "Shares":
        _check_compat(self, other)
        return Shares(field.mul(self.values, other.values),
                      self.degree + other.degree)

    def add_public(self, const) -> "Shares":
        """Add a public constant (affects the free coefficient only)."""
        return Shares(field.add(self.values, field.to_field(const).astype(DTYPE)),
                      self.degree)

    def mul_public(self, const) -> "Shares":
        return Shares(field.mul(self.values, field.to_field(const).astype(DTYPE)),
                      self.degree)

    def neg(self) -> "Shares":
        return Shares(field.neg(self.values), self.degree)

    def sum(self, axis=None, keepdims: bool = False) -> "Shares":
        """Modular sum over secret-data axes (axis indexes self.shape)."""
        if axis is None:
            axes = tuple(range(1, self.values.ndim))
        elif isinstance(axis, int):
            axes = (_norm_axis(axis, self.values.ndim - 1) + 1,)
        else:
            axes = tuple(_norm_axis(a, self.values.ndim - 1) + 1 for a in axis)
        return Shares(field.sum_(self.values, axis=axes, keepdims=keepdims),
                      self.degree)

    def reshape(self, *shape) -> "Shares":
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        return Shares(self.values.reshape((self.n_shares,) + tuple(shape)),
                      self.degree)

    def __getitem__(self, idx) -> "Shares":
        """Index the *secret data* dims (cloud axis is preserved)."""
        if not isinstance(idx, tuple):
            idx = (idx,)
        return Shares(self.values[(slice(None),) + idx], self.degree)


def _norm_axis(a: int, ndim: int) -> int:
    return a + ndim if a < 0 else a


def _check_compat(a: Shares, b: Shares) -> None:
    if a.n_shares != b.n_shares:
        raise ValueError(f"share-count mismatch: {a.n_shares} vs {b.n_shares}")


# ---------------------------------------------------------------------------
# Share generation
# ---------------------------------------------------------------------------

def eval_points(n_shares: int) -> jax.Array:
    """Public evaluation points x_k = 1..c (never 0)."""
    return jnp.arange(1, n_shares + 1, dtype=DTYPE)


@functools.partial(jax.jit, static_argnames=("n_shares", "degree"))
def make_shares(key: jax.Array, secrets: jax.Array, *, n_shares: int,
                degree: int = 1) -> jax.Array:
    """Create ``n_shares`` Shamir shares of every element of ``secrets``.

    Every element gets an independent random polynomial (paper §2.1: multiple
    occurrences of a value must have different shares).

    Returns uint32[n_shares, *secrets.shape].
    """
    secrets = field.to_field(secrets).astype(DTYPE)
    coeffs = field.uniform(key, (degree,) + secrets.shape)      # a_1..a_t
    xs = eval_points(n_shares)                                   # (c,)
    # shares[k] = s + sum_t a_t * x_k^t  (Horner over t, vectorized over k)
    def horner(k_x):
        acc = jnp.zeros_like(secrets)
        for t in range(degree - 1, -1, -1):
            acc = field.add(field.mul(acc, jnp.broadcast_to(k_x, acc.shape)),
                            coeffs[t])
        return field.add(field.mul(acc, jnp.broadcast_to(k_x, acc.shape)),
                         secrets)
    return jax.vmap(horner)(xs)


def share(key: jax.Array, secrets, *, n_shares: int, degree: int = 1) -> Shares:
    secrets = jnp.asarray(secrets)
    return Shares(make_shares(key, secrets, n_shares=n_shares, degree=degree),
                  degree)


# ---------------------------------------------------------------------------
# Lagrange interpolation (the user-side "q_interpolate" of §2.2)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _lagrange_at_zero_np(points: tuple) -> np.ndarray:
    """λ_j = Π_{i≠j} x_i / (x_i − x_j) mod p, as numpy uint32 (host-side)."""
    p = int(P)
    xs = [int(x) for x in points]
    lams = []
    for j, xj in enumerate(xs):
        num, den = 1, 1
        for i, xi in enumerate(xs):
            if i == j:
                continue
            num = (num * xi) % p
            den = (den * (xi - xj)) % p
        lams.append((num * pow(den, p - 2, p)) % p)
    return np.asarray(lams, dtype=np.uint32)


def lagrange_coeffs(n_points: int, points: Optional[tuple] = None) -> jax.Array:
    pts = points if points is not None else tuple(range(1, n_points + 1))
    return jnp.asarray(_lagrange_at_zero_np(tuple(int(x) for x in pts)))


def interpolate(shares: Shares, *, points: Optional[tuple] = None) -> jax.Array:
    """Reconstruct secrets from the first ``degree+1`` shares (or all).

    Uses exactly ``degree+1`` shares when available — the user contacts c′
    clouds, not all c (paper §2).
    """
    need = shares.degree + 1
    if shares.n_shares < need:
        raise ValueError(
            f"need {need} shares to open a degree-{shares.degree} sharing, "
            f"have {shares.n_shares}")
    vals = shares.values[:need]
    pts = points if points is not None else tuple(range(1, need + 1))
    lam = lagrange_coeffs(need, pts)                       # (c',)
    lam = lam.reshape((need,) + (1,) * (vals.ndim - 1))
    return field.sum_(field.mul(vals, jnp.broadcast_to(lam, vals.shape)),
                      axis=0)


def verify_consistency(shares: Shares) -> jax.Array:
    """Berlekamp–Welch-style *detection* hook (paper §2.1 "Aside").

    With r = n_shares − (degree+1) redundant shares, an honest-but-wrong
    (or malicious) cloud is detected by checking that every share lies on the
    unique degree-``t`` polynomial through the first t+1 shares. Returns a
    boolean array (True = consistent) of the secret shape.
    """
    t1 = shares.degree + 1
    if shares.n_shares <= t1:
        return jnp.ones(shares.shape, dtype=bool)
    ok = jnp.ones(shares.shape, dtype=bool)
    base_pts = tuple(range(1, t1 + 1))
    for extra in range(t1, shares.n_shares):
        # interpolate *at x_extra* from the first t+1 shares
        xe = extra + 1
        lam = _lagrange_at(tuple(base_pts), xe)
        pred = field.sum_(
            field.mul(shares.values[:t1],
                      jnp.broadcast_to(
                          lam.reshape((t1,) + (1,) * (shares.values.ndim - 1)),
                          shares.values[:t1].shape)), axis=0)
        ok = ok & (pred == shares.values[extra])
    return ok


@functools.lru_cache(maxsize=256)
def _lagrange_at_np(points: tuple, x0: int) -> np.ndarray:
    p = int(P)
    xs = [int(x) for x in points]
    lams = []
    for j, xj in enumerate(xs):
        num, den = 1, 1
        for i, xi in enumerate(xs):
            if i == j:
                continue
            num = (num * (x0 - xi)) % p
            den = (den * (xj - xi)) % p
        lams.append((num * pow(den, p - 2, p)) % p)
    return np.asarray(lams, dtype=np.uint32)


def _lagrange_at(points: tuple, x0: int) -> jax.Array:
    return jnp.asarray(_lagrange_at_np(points, x0))


# ---------------------------------------------------------------------------
# Degree reduction (re-sharing; §3.4 / [32])
# ---------------------------------------------------------------------------

def reduce_degree(key: jax.Array, shares: Shares, *, target_degree: int = 1
                  ) -> Shares:
    """Re-share a high-degree sharing down to ``target_degree``.

    Protocol: cloud k re-shares its share s_k with a fresh degree-t polynomial
    (sub-shares [k -> j]); cloud j combines sub-shares with the Lagrange
    weights λ_k of the *high-degree* opening:  s'_j = Σ_k λ_k · sub_{k→j}.
    Correct because interpolation is linear. This crosses the cloud axis —
    it is the protocol's explicit communication round.
    """
    d = shares.degree
    c = shares.n_shares
    need = d + 1
    if c < need:
        raise ValueError(f"cannot reduce degree {d} with only {c} shares")
    lam = lagrange_coeffs(need)                                 # (d+1,)
    # sub[k, j, ...] = share_{k -> j}
    sub = make_shares(key, shares.values[:need], n_shares=c,
                      degree=target_degree)                     # (c, d+1, ...)
    lam_b = lam.reshape((1, need) + (1,) * (shares.values.ndim - 1))
    new_vals = field.sum_(
        field.mul(sub, jnp.broadcast_to(lam_b, sub.shape)), axis=1)
    return Shares(new_vals, target_degree)
