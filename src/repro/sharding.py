"""Named-axis sharding rules for every arch family × shape cell.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod. Batch (or sequence, when batch is unshardable) spreads over
``pod``×``data``; parameters spread over ``model``.

Divisibility-driven fallbacks (recorded per-arch in EXPERIMENTS.md §Dry-run):
  * attention heads shard on ``model`` iff n_heads % model == 0
    (else attention weights replicate — vocab/FFN still shard);
  * KV heads shard iff n_kv_heads % model == 0, else KV weights replicate
    (the Megatron "replicated-KV" GQA trick);
  * KV *caches* whose head axis cannot shard are **context-parallel**:
    the sequence axis shards on ``model`` (baseline: XLA gathers; the
    shard_map ring-combine is a §Perf hillclimb);
  * vocab shards iff vocab % model == 0, else the embedding shards on
    d_model;
  * MoE experts shard (EP) iff n_experts % model == 0, else expert FFN dim
    shards (TP);
  * SSM heads shard iff ssm_n_heads % model == 0 (head-shaped params make
    this a pure layout choice — see models/ssm.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .models.config import ModelConfig, ShapeConfig

Rep = P()


def dp_axes(mesh: Mesh):
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def model_size(mesh: Mesh) -> int:
    return int(mesh.shape["model"])


class Divisibility:
    def __init__(self, cfg: ModelConfig, mesh: Mesh):
        m = model_size(mesh)
        self.m = m
        self.q = cfg.n_heads % m == 0
        self.kv = cfg.n_kv_heads % m == 0
        self.ff = cfg.d_ff % m == 0 and cfg.d_ff > 0
        self.experts = cfg.n_experts % m == 0 and cfg.n_experts > 0
        self.vocab = cfg.vocab_size % m == 0
        self.d = cfg.d_model % m == 0
        self.ssm_h = (cfg.ssm_n_heads % m == 0
                      if (cfg.family == "ssm" or cfg.hybrid_ssm) else False)
        self.mla_q = cfg.attn_type == "mla" and cfg.n_heads % m == 0


def _attn_spec(name: str, ndim: int, div: Divisibility) -> P:
    """Specs for attention leaves; leading L axis already accounted (ndim)."""
    lead = (None,) * (ndim - 2)
    if name in ("wq", "wuq"):
        return P(*lead, None, "model") if div.q else Rep
    if name in ("wk", "wv"):
        return P(*lead, None, "model") if div.kv else Rep
    if name in ("wuk", "wuv"):
        return P(*lead, None, "model") if div.q else Rep
    if name == "wo":
        return P(*lead, "model", None) if div.q else Rep
    if name == "bq":
        return P(*(None,) * (ndim - 1), "model") if div.q else Rep
    if name in ("bk", "bv"):
        return P(*(None,) * (ndim - 1), "model") if div.kv else Rep
    return Rep  # norms, wdq, wdkv, scalars


def param_specs(cfg: ModelConfig, mesh: Mesh, params_tree) -> Any:
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays)."""
    div = Divisibility(cfg, mesh)

    def rule(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        ndim = len(leaf.shape)
        in_block = any(n in ("blocks", "enc_blocks") for n in names)
        lead = (None,) * (ndim - 2)
        if name == "embed":
            if div.vocab:
                return P("model", None)
            return P(None, "model") if div.d else Rep
        if name == "lm_head":
            if div.vocab:
                return P(None, "model")
            return P("model", None) if div.d else Rep
        if name == "frontend_proj":
            return P(None, "model") if div.d else Rep
        if not in_block:
            return Rep
        # ---- inside a (stacked) block: names[1] is the submodule ----------
        if "attn" in names or "cross" in names:
            return _attn_spec(name, ndim, div)
        if "moe" in names and "shared" not in names:
            if name == "router":
                return Rep
            if name in ("w_gate", "w_up"):        # (L, E, D, F)
                if div.experts:
                    return P(None, "model", None, None)
                return P(None, None, None, "model") if div.ff else Rep
            if name == "w_down":                   # (L, E, F, D)
                if div.experts:
                    return P(None, "model", None, None)
                return P(None, None, "model", None) if div.ff else Rep
            # shared expert falls through to mlp rules below
        if "mlp" in names or "shared" in names:
            if name in ("w_gate", "w_up"):         # (L, D, F)
                return P(*lead, None, "model") if div.ff else Rep
            if name == "w_down":                   # (L, F, D)
                return P(*lead, "model", None) if div.ff else Rep
            return Rep
        if "ssm" in names:
            if not div.ssm_h:
                return Rep
            if name in ("w_z", "w_x"):             # (L, D, H, P)
                return P(None, None, "model", None)
            if name == "conv_x":                   # (L, k, H, P)
                return P(None, None, "model", None)
            if name in ("conv_bx", "norm"):        # (L, H, P)
                return P(None, "model", None)
            if name in ("dt_bias", "A_log", "D"):  # (L, H)
                return P(None, "model")
            if name == "w_dt":                     # (L, D, H)
                return P(None, None, "model")
            if name == "out_proj":                 # (L, H, P, D)
                return P(None, "model", None, None)
            return Rep
        return Rep

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh, params_tree))


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig
               ) -> Dict[str, P]:
    dp = dp_axes(mesh)
    shard_b = shape.global_batch % dp_size(mesh) == 0
    bspec = P(dp) if shard_b else Rep
    out = {"tokens": P(*bspec, None) if shard_b else P(None, None)}
    if shape.kind == "train":
        out["labels"] = out["tokens"]
    if cfg.frontend == "vit":
        out["patches"] = P(*bspec, None, None) if shard_b else Rep
    if cfg.frontend == "audio" and shape.kind != "decode":
        out["frames"] = P(*bspec, None, None) if shard_b else Rep
    return out


def cache_spec(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> Any:
    """Spec pytree matching ``models.init_cache`` structure."""
    dp = dp_axes(mesh)
    div = Divisibility(cfg, mesh)
    shard_b = shape.global_batch % dp_size(mesh) == 0
    b_ax = dp if shard_b else None
    # sequence axis: shard over dp when batch can't shard (long-context);
    # shard over model when KV heads can't (context-parallel cache).
    s_ax_from_b = None if shard_b else dp

    cache: Dict[str, Any] = {}
    if cfg.family != "ssm":
        if cfg.attn_type == "mla":
            # (L, B, S, r): sequence on "model". (Alternative evaluated in
            # §Perf iter 3 — rank-dim sharding localizes the cache DUS but
            # adds a (B,H,S) score psum per layer that costs more than the
            # masked-select rewrite it removes: 0.27s vs 0.125s total.
            # Refuted; kept S-sharding.)
            s_ax = s_ax_from_b if s_ax_from_b else "model"
            cache["kv"] = (P(None, b_ax, s_ax, None),
                           P(None, b_ax, s_ax, None))
        else:
            # (L, B, S, Hkv, hd)
            if div.kv:
                h_ax, s_ax = "model", s_ax_from_b
            else:
                h_ax, s_ax = None, (s_ax_from_b or "model")
            cache["kv"] = (P(None, b_ax, s_ax, h_ax, None),
                           P(None, b_ax, s_ax, h_ax, None))
    if cfg.family == "ssm" or cfg.hybrid_ssm:
        h_ax = "model" if div.ssm_h else None
        from .models.ssm import SSMCache
        cache["ssm"] = SSMCache(
            conv_x=P(None, b_ax, None, h_ax, None),
            conv_B=P(None, b_ax, None, None),
            conv_C=P(None, b_ax, None, None),
            state=P(None, b_ax, h_ax, None, None))
    if cfg.n_enc_layers:
        h_ax = "model" if div.kv else None
        cache["cross"] = (P(None, b_ax, None, h_ax, None),
                          P(None, b_ax, None, h_ax, None))
    return cache


# ---------------------------------------------------------------------------
# secret-shared relations (repro.core.mesh_dispatch)
# ---------------------------------------------------------------------------

def share_spec(mesh: Mesh, shape: Tuple[int, ...]) -> P:
    """Spec for a raw share array ``(c, n, ...)`` of an outsourced relation.

    The cloud axis (the c Shamir shares — the paper's non-communicating
    clouds) spreads over ``model``; the tuple axis spreads over the data
    axes exactly like a batch. A non-divisible axis replicates — placement
    is pure layout and must never constrain relation or share-count shapes.
    Trailing word/bit axes always replicate (they ride inside one cloud's
    slice of one tuple).
    """
    c_ax = ("model" if ("model" in mesh.axis_names
                        and shape[0] % model_size(mesh) == 0) else None)
    if len(shape) <= 1:
        return P(c_ax)
    t_ax = dp_axes(mesh) if shape[1] % dp_size(mesh) == 0 else None
    return P(c_ax, t_ax)


def logits_spec(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> P:
    dp = dp_axes(mesh)
    div = Divisibility(cfg, mesh)
    shard_b = shape.global_batch % dp_size(mesh) == 0
    return P(dp if shard_b else None, None, "model" if div.vocab else None)
