from .mapreduce import MapReduceRunner, WorkerPool, TaskResult

__all__ = ["MapReduceRunner", "WorkerPool", "TaskResult"]
