"""Fault-tolerant MapReduce runtime — the paper's execution substrate.

The paper runs its oblivious queries as MapReduce jobs: a *master* assigns
map tasks over input splits and reduce tasks over keyed groups; the original
MapReduce fault model (Dean & Ghemawat, OSDI'04) re-executes lost tasks and
launches **speculative backup tasks** for stragglers. This module implements
that master faithfully:

  * worker pool with heartbeats; a worker that misses its lease deadline is
    declared dead and its in-flight task re-queued;
  * injected fault hooks (``fail_prob``, ``slow_factor``) so tests can kill
    workers and create stragglers deterministically;
  * speculative execution: when ≥ ``spec_threshold`` of tasks have finished,
    backup copies of the stragglers are issued; first result wins
    (map tasks are pure/idempotent — share-space programs have no side
    effects, so duplicate execution is safe);
  * wave-based elasticity: workers may be added/removed between waves.

At cluster scale each "worker" is a TPU host driving a jitted shard program;
here workers are threads driving the same jitted functions on CPU — the
scheduling logic is identical and is what the tests exercise.

Two callers sit on top of this runner: the backend wrapper
(``repro.api.executor.MapReduceExecutor.wrap`` — each hot op splits its own
data axis into map tasks) and the sharded-dataplane placement policy
(``repro.api.executor.MapReduceDispatcher`` — the round engine already
emitted one dispatch per tuple-axis shard of a
``repro.core.dataplane.ShardedRelation``; each shard dispatch becomes one
map task here, inheriting re-execution and speculative backups). ``splits``
is any sequence of task payloads — input-split bounds for the wrapper,
zero-argument thunks for the dispatcher.
"""
from __future__ import annotations

import dataclasses
import queue
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence


@dataclasses.dataclass
class TaskResult:
    task_id: int
    value: Any
    worker: int
    attempt: int
    duration_s: float


@dataclasses.dataclass
class _Attempt:
    task_id: int
    attempt: int
    worker: int
    started: float
    deadline: float


class WorkerPool:
    """Threads with injected failures/slowness, heartbeat-observable."""

    def __init__(self, n_workers: int, *, fail_prob: float = 0.0,
                 slow_workers: Optional[Dict[int, float]] = None,
                 dead_workers: Optional[set] = None, seed: int = 0):
        self.n = n_workers
        self.fail_prob = fail_prob
        self.slow = slow_workers or {}
        self.dead = dead_workers or set()
        self.rng = random.Random(seed)


class MapReduceRunner:
    """run(map_fn, splits, reduce_fn) with re-execution + backup tasks."""

    def __init__(self, pool: WorkerPool, *, lease_s: float = 2.0,
                 spec_threshold: float = 0.75, max_attempts: int = 4,
                 poll_s: float = 0.01):
        self.pool = pool
        self.lease_s = lease_s
        self.spec_threshold = spec_threshold
        self.max_attempts = max_attempts
        self.poll_s = poll_s
        # telemetry the tests assert on
        self.reexecutions = 0
        self.speculative_launched = 0
        self.worker_deaths = 0

    # -- internals ----------------------------------------------------------
    def _exec(self, map_fn, splits, task_id: int, attempt: int, worker: int,
              out_q: "queue.Queue"):
        t0 = time.time()
        slow = self.pool.slow.get(worker, 0.0)
        if slow:
            time.sleep(slow)
        if worker in self.pool.dead:
            return  # silent death: no result, no heartbeat -> lease expiry
        if self.pool.rng.random() < self.pool.fail_prob:
            return  # crashed mid-task
        try:
            value = map_fn(splits[task_id])
        except Exception as e:  # noqa: BLE001 — surfaced via queue
            out_q.put(("error", task_id, attempt, worker, e))
            return
        out_q.put(("ok", TaskResult(task_id, value, worker, attempt,
                                    time.time() - t0)))

    def run(self, map_fn: Callable[[Any], Any], splits: Sequence[Any],
            reduce_fn: Optional[Callable[[List[Any]], Any]] = None) -> Any:
        n = len(splits)
        results: Dict[int, TaskResult] = {}
        attempts: Dict[int, int] = {i: 0 for i in range(n)}
        inflight: List[_Attempt] = []
        out_q: "queue.Queue" = queue.Queue()
        pending = list(range(n))
        next_worker = [0]

        def launch(task_id: int):
            w = next_worker[0] % self.pool.n
            next_worker[0] += 1
            attempts[task_id] += 1
            att = attempts[task_id]
            if att > self.max_attempts:
                raise RuntimeError(f"task {task_id} exceeded max attempts")
            rec = _Attempt(task_id, att, w, time.time(),
                           time.time() + self.lease_s)
            inflight.append(rec)
            th = threading.Thread(
                target=self._exec, args=(map_fn, splits, task_id, att, w,
                                         out_q), daemon=True)
            th.start()

        while pending:
            launch(pending.pop(0))

        spec_done = False
        while len(results) < n:
            # drain ALL queued results this iteration: with many splits,
            # taking one per poll would add up to poll_s latency per
            # completed task.
            ready = []
            try:
                ready.append(out_q.get(timeout=self.poll_s))
                while True:
                    ready.append(out_q.get_nowait())
            except queue.Empty:
                pass
            for kind, *payload in ready:
                if kind == "ok":
                    res: TaskResult = payload[0]
                    if res.task_id not in results:   # first result wins
                        results[res.task_id] = res
                    inflight[:] = [a for a in inflight
                                   if a.task_id != res.task_id]
                else:
                    _, task_id, attempt, worker, err = (kind, *payload)
                    raise err
            now = time.time()
            # lease expiry -> declare worker dead, re-execute
            expired = [a for a in inflight if a.deadline < now
                       and a.task_id not in results]
            for a in expired:
                inflight.remove(a)
                self.worker_deaths += 1
                self.reexecutions += 1
                launch(a.task_id)
            # speculative backups for stragglers
            if (not spec_done
                    and len(results) >= self.spec_threshold * n):
                stragglers = {a.task_id for a in inflight
                              if a.task_id not in results}
                for t in stragglers:
                    self.speculative_launched += 1
                    launch(t)
                spec_done = True
        ordered = [results[i].value for i in range(n)]
        return reduce_fn(ordered) if reduce_fn else ordered
