"""Protocol-cost regression gate + trajectory history for BENCH_queries.json.

Diffs a fresh ``BENCH_queries.json`` against a previous run's artifact (the
CI bench-smoke lane uploads one per PR). Protocol costs — communication
rounds and bits per (bench, name, n) configuration — are *deterministic*
functions of the protocol, so any increase is a real regression, not noise;
wall-times are reported but never gated (they jitter with the runner) —
with one carve-out: the ``mesh`` section's steady-state wall time is gated
behind a generous tolerance factor (``MESH_WALL_TOLERANCE``), because the
device-resident dispatcher exists *for* speed and its HLO-predicted costs
(FLOPs / HBM bytes / collective bytes, also gated, fully deterministic)
anchor what the wall time should be.

Exit status: 0 = no protocol-cost regressions, 1 = regression(s) found,
2 = the artifacts could not be loaded/compared.

Usage::

  PYTHONPATH=src python benchmarks/compare_bench.py NEW.json BASELINE.json
      [--allow-missing]   # dropped configs are reported but not fatal
      [--append-history BENCH_history.json [--history-label LABEL]]

New configurations (queries added since the baseline) are informational.
A configuration present in the baseline but missing from the fresh run is
treated as a regression unless ``--allow-missing`` is given — silently
dropping a bench row is how cost regressions hide.

``--append-history`` chains the fresh run's per-config protocol costs
(rounds, comm_bits — the gated keys) onto a schema-versioned time series
(``bench_history/v1``), one entry per run, so the cost trajectory across
PRs is plottable instead of only pairwise-gated. With it, ``BASELINE.json``
may be omitted (first run: nothing to gate, still worth recording).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

#: per-config protocol costs that must never increase (deterministic).
GATED_KEYS = ("rounds", "comm_bits")
#: deterministic cloud/user work — drift is surfaced but not fatal (a PR
#: may legitimately trade cloud work for communication).
INFO_KEYS = ("cloud_bits", "user_bits")
#: mesh section: deterministic HLO-predicted costs gate like protocol
#: costs; the measured wall time gates behind this tolerance factor
#: (fresh wall > baseline wall x tolerance == regression — generous
#: enough to absorb runner jitter, tight enough to catch a lost
#: device-residency or fusion).
MESH_PREDICTED_KEYS = ("predicted_flops", "predicted_hbm_bytes",
                       "predicted_collective_bytes")
MESH_WALL_TOLERANCE = 2.0
#: serving_storm section: the cold neighbour's p95 under a 10x hot-tenant
#: flood must stay within this factor of its solo baseline — the
#: self-tuning overload machinery (weighted fair quotas, adaptive
#: deadline steering, fused closes) exists *for* this ratio. Both runs
#: execute on the same machine so runner speed divides out; the ceiling
#: is env-overridable for noisy runners (like MESH_WALL_TOLERANCE would
#: be raised, but p95 ratios jitter more than steady-state walls).
STORM_P95_TOLERANCE = float(os.environ.get("STORM_P95_TOLERANCE", "1.5"))


def _load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "bench_queries/v1":
        raise ValueError(f"{path}: unknown schema {doc.get('schema')!r}")
    return doc


def index_results(doc: dict) -> Dict[Tuple[str, str, int], dict]:
    return {(r["bench"], r["name"], r["n"]): r for r in doc["results"]}


def index_batched(doc: dict) -> Dict[Tuple[str, int, int], dict]:
    return {(r["name"], r["batch"], r["n"]): r for r in doc["batched"]}


def index_sharded(doc: dict) -> Dict[Tuple[str, int, int], dict]:
    # "sharded" arrived after v1 baselines were already uploaded — absent
    # means an old artifact, not a dropped section.
    return {(r["name"], r["shards"], r["n"]): r
            for r in doc.get("sharded", [])}


def index_serving(doc: dict) -> Dict[Tuple[str, int, int], dict]:
    # "serving" (multi-tenant sweep) post-dates "sharded" the same way.
    return {(r["name"], r["relations"], r["n"]): r
            for r in doc.get("serving", [])}


def index_serving_storm(doc: dict) -> Dict[Tuple[str, int, int], dict]:
    # "serving_storm" (overload isolation) post-dates "embedding".
    return {(r["name"], r["hot_ratio"], r["n"]): r
            for r in doc.get("serving_storm", [])}


def index_aggregation(doc: dict) -> Dict[Tuple[str, int, int], dict]:
    # "aggregation" (SUM/AVG/MIN-MAX + verification) post-dates "serving".
    return {(r["name"], r["batch"], r["n"]): r
            for r in doc.get("aggregation", [])}


def index_pattern(doc: dict) -> Dict[Tuple[str, int], dict]:
    # "pattern" (LIKE/prefix/suffix/substring engine) post-dates
    # "embedding".
    return {(r["name"], r["n"]): r for r in doc.get("pattern", [])}


def index_mesh(doc: dict) -> Dict[Tuple[str, int, int], dict]:
    # "mesh" (device-resident dispatcher) post-dates "aggregation".
    return {(r["name"], r["shards"], r["n"]): r
            for r in doc.get("mesh", [])}


def index_embedding(doc: dict) -> Dict[Tuple[str, int, int], dict]:
    # "embedding" (oblivious embedding fast path) post-dates "mesh".
    return {(r["name"], r["shards"], r["n_tokens"]): r
            for r in doc.get("embedding", [])}


#: embedding section: tokens/sec over the per-call baseline must stay at or
#: above the acceptance floor — the fast path exists *for* this ratio, and
#: the baseline runs on the same machine so runner speed divides out.
EMBED_SPEEDUP_FLOOR = 5.0


def compare(new: dict, old: dict, *, allow_missing: bool = False
            ) -> Tuple[List[str], List[str]]:
    """-> (regressions, notes). Empty regressions == gate passes."""
    regressions: List[str] = []
    notes: List[str] = []

    def diff_rows(kind, new_idx, old_idx, gated, info=()):
        for key, old_row in old_idx.items():
            tag = f"{kind} {'/'.join(str(k) for k in key)}"
            new_row = new_idx.get(key)
            if new_row is None:
                msg = f"{tag}: config vanished from the fresh run"
                (notes if allow_missing else regressions).append(msg)
                continue
            for field in gated:
                if new_row[field] > old_row[field]:
                    regressions.append(
                        f"{tag}: {field} {old_row[field]} -> "
                        f"{new_row[field]} (+{new_row[field] - old_row[field]})")
            for field in info:
                if new_row[field] != old_row[field]:
                    notes.append(f"{tag}: {field} {old_row[field]} -> "
                                 f"{new_row[field]}")
        for key in new_idx.keys() - old_idx.keys():
            notes.append(f"{kind} {'/'.join(str(k) for k in key)}: "
                         f"new config (no baseline)")

    diff_rows("table", index_results(new), index_results(old),
              GATED_KEYS, INFO_KEYS)
    diff_rows("batched", index_batched(new), index_batched(old),
              GATED_KEYS)
    diff_rows("sharded", index_sharded(new), index_sharded(old),
              GATED_KEYS)
    diff_rows("serving", index_serving(new), index_serving(old),
              GATED_KEYS)
    diff_rows("serving_storm", index_serving_storm(new),
              index_serving_storm(old), GATED_KEYS)
    diff_rows("aggregation", index_aggregation(new), index_aggregation(old),
              GATED_KEYS + ("verify_rounds", "verify_comm_bits"))
    diff_rows("pattern", index_pattern(new), index_pattern(old),
              GATED_KEYS)
    diff_rows("mesh", index_mesh(new), index_mesh(old), GATED_KEYS)
    diff_rows("embedding", index_embedding(new), index_embedding(old),
              GATED_KEYS + ("verify_rounds", "verify_comm_bits",
                            "per_token_bits", "dispatches_per_step"))
    # mesh speed gate: predicted costs are deterministic per device count,
    # wall time gets the tolerance factor — both only comparable when the
    # runs saw the same device mesh.
    new_mesh, old_mesh = index_mesh(new), index_mesh(old)
    for key, old_row in old_mesh.items():
        new_row = new_mesh.get(key)
        if new_row is None:
            continue                       # vanishing handled by diff_rows
        tag = f"mesh {'/'.join(str(k) for k in key)}"
        if new_row.get("devices") != old_row.get("devices"):
            notes.append(f"{tag}: device count changed "
                         f"({old_row.get('devices')} -> "
                         f"{new_row.get('devices')}), speed gate skipped")
            continue
        for field in MESH_PREDICTED_KEYS:
            if new_row[field] > old_row[field]:
                regressions.append(
                    f"{tag}: {field} {old_row[field]} -> {new_row[field]} "
                    f"(+{new_row[field] - old_row[field]})")
        limit = old_row["wall_us"] * MESH_WALL_TOLERANCE
        if new_row["wall_us"] > limit:
            regressions.append(
                f"{tag}: wall_us {old_row['wall_us']} -> "
                f"{new_row['wall_us']} (> {MESH_WALL_TOLERANCE}x baseline "
                f"— device-resident path slowed down)")
    for key, row in index_batched(new).items():
        if not row.get("ledger_equal", False):
            regressions.append(
                f"batched {'/'.join(str(k) for k in key)}: "
                f"batch != sequential ledger (fusion broke cost identity)")
    for key, row in index_sharded(new).items():
        if not row.get("ledger_equal", False):
            regressions.append(
                f"sharded {'/'.join(str(k) for k in key)}: "
                f"sharded != unsharded ledger (dataplane broke the "
                f"transcript identity)")
    for key, row in index_serving(new).items():
        if not row.get("ledger_equal", False):
            regressions.append(
                f"serving {'/'.join(str(k) for k in key)}: "
                f"multi-tenant != solo-server ledger (cross-relation "
                f"routing broke tenant isolation)")
    for key, row in index_serving_storm(new).items():
        tag = f"serving_storm {'/'.join(str(k) for k in key)}"
        if not row.get("ledger_equal", False):
            regressions.append(
                f"{tag}: storm perturbed the neighbour's transcript "
                f"(rows or ledgers differ from the solo run)")
        if row.get("p95_ratio", 0.0) > STORM_P95_TOLERANCE:
            regressions.append(
                f"{tag}: neighbour p95 ratio {row.get('p95_ratio')} over "
                f"the {STORM_P95_TOLERANCE}x solo ceiling (overload "
                f"isolation lost — hot tenant leaking latency into its "
                f"neighbour)")
        if not row.get("steering_diverged", False):
            regressions.append(
                f"{tag}: steered deadlines failed to diverge (hot "
                f"{row.get('hot_steered_wait_ms')}ms !< cold "
                f"{row.get('cold_steered_wait_ms')}ms — adaptive "
                f"steering inert under a 10x flood)")
    for key, row in index_aggregation(new).items():
        if not row.get("ledger_equal", False):
            regressions.append(
                f"aggregation {'/'.join(str(k) for k in key)}: "
                f"batch != sequential ledger (aggregate fusion broke "
                f"cost identity)")
    for key, row in index_pattern(new).items():
        tag = f"pattern {'/'.join(str(k) for k in key)}"
        if not row.get("ledger_equal", True):
            regressions.append(
                f"{tag}: batch != sequential ledger (pattern fusion "
                f"broke cost identity)")
        if not row.get("explain_exact", True):
            regressions.append(
                f"{tag}: planner estimate != measured ledger (pattern "
                f"cost model drifted from the round engine)")
        if not row.get("eq_parity", True):
            regressions.append(
                f"{tag}: wildcard-free LIKE no longer lowers to the Eq "
                f"path bit-for-bit")
    for key, row in index_mesh(new).items():
        if not row.get("ledger_equal", False):
            regressions.append(
                f"mesh {'/'.join(str(k) for k in key)}: "
                f"mesh != serial ledger (device placement broke the "
                f"transcript identity)")
    for key, row in index_embedding(new).items():
        tag = f"embedding {'/'.join(str(k) for k in key)}"
        if not row.get("ledger_equal", False):
            regressions.append(
                f"{tag}: batched != sequential ledger (lookup fusion "
                f"broke cost identity)")
        if row.get("speedup", 0.0) < EMBED_SPEEDUP_FLOOR:
            regressions.append(
                f"{tag}: speedup {row.get('speedup')} fell below the "
                f"{EMBED_SPEEDUP_FLOOR}x acceptance floor over the "
                f"per-call baseline")
        if row.get("dispatches_per_step") != row.get("shards"):
            regressions.append(
                f"{tag}: {row.get('dispatches_per_step')} dispatches per "
                f"decode step with {row.get('shards')} shards (want ONE "
                f"fused ss_matmul per shard)")
    return regressions, notes


# ---------------------------------------------------------------------------
# trajectory history (bench_history/v1)
# ---------------------------------------------------------------------------

HISTORY_SCHEMA = "bench_history/v1"


def history_entry(doc: dict, label: str) -> dict:
    """One run's gated protocol costs, keyed like the comparator."""

    def costs(idx, fields=GATED_KEYS):
        return {"/".join(str(k) for k in key):
                {f: row[f] for f in fields}
                for key, row in sorted(idx.items(), key=str)}

    return dict(label=label, smoke=bool(doc.get("smoke")),
                table=costs(index_results(doc)),
                batched=costs(index_batched(doc)),
                sharded=costs(index_sharded(doc)),
                serving=costs(index_serving(doc)),
                serving_storm=costs(index_serving_storm(doc),
                                    GATED_KEYS + ("p95_ratio",
                                                  "hot_steered_wait_ms",
                                                  "cold_steered_wait_ms")),
                aggregation=costs(index_aggregation(doc)),
                pattern=costs(index_pattern(doc)),
                mesh=costs(index_mesh(doc),
                           GATED_KEYS + MESH_PREDICTED_KEYS
                           + ("wall_us", "devices")),
                embedding=costs(index_embedding(doc),
                                GATED_KEYS + ("per_token_bits",
                                              "dispatches_per_step",
                                              "tokens_per_sec", "speedup")))


def append_history(doc: dict, history: Optional[dict], label: str) -> dict:
    """Chain ``doc``'s protocol costs onto the (possibly new) history."""
    if history is None:
        history = dict(schema=HISTORY_SCHEMA, runs=[])
    validate_history(history)
    history["runs"].append(history_entry(doc, label))
    return history


def validate_history(history: dict) -> None:
    """Raise ValueError on a malformed history document."""
    if history.get("schema") != HISTORY_SCHEMA:
        raise ValueError(f"unknown history schema "
                         f"{history.get('schema')!r}")
    runs = history.get("runs")
    if not isinstance(runs, list):
        raise ValueError("history.runs must be a list")
    for run in runs:
        if "label" not in run:
            raise ValueError("history run without a label")
        for section in ("table", "batched", "sharded", "serving",
                        "serving_storm", "aggregation", "pattern", "mesh",
                        "embedding"):
            costs_by_cfg = run.get(section)
            if not isinstance(costs_by_cfg, dict):
                continue     # absent / experimental payload: not ours to gate
            for cfg, costs in costs_by_cfg.items():
                missing = [f for f in GATED_KEYS if f not in costs]
                if missing:
                    raise ValueError(
                        f"history run {run['label']!r} {section}/{cfg} "
                        f"missing {missing}")


def load_history(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="fresh BENCH_queries.json")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="previous run's BENCH_queries.json (optional when "
                         "only appending history)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="dropped configs are notes, not regressions")
    ap.add_argument("--append-history", metavar="PATH", default=None,
                    help="append this run's gated costs to the "
                         "bench_history/v1 time series at PATH "
                         "(created if absent)")
    ap.add_argument("--history-label", default=None,
                    help="label for the history entry (default: "
                         "$GITHUB_SHA or run-<N>)")
    args = ap.parse_args(argv)
    if args.baseline is None and args.append_history is None:
        ap.error("a BASELINE to compare against is required unless "
                 "--append-history is given")
    try:
        new = _load(args.new)
        regressions: List[str] = []
        notes: List[str] = []
        if args.baseline is not None:
            old = _load(args.baseline)
            regressions, notes = compare(new, old,
                                         allow_missing=args.allow_missing)
        if args.append_history:
            history = load_history(args.append_history)
            n_prev = len(history["runs"]) if history else 0
            label = (args.history_label
                     or os.environ.get("GITHUB_SHA", "")[:12]
                     or f"run-{n_prev + 1}")
            history = append_history(new, history, label)
            validate_history(history)
            with open(args.append_history, "w") as f:
                json.dump(history, f, indent=2)
            print(f"history: appended {label!r} to {args.append_history} "
                  f"({len(history['runs'])} runs)")
    except (OSError, ValueError, KeyError) as e:
        print(f"compare_bench: cannot compare: {e}", file=sys.stderr)
        return 2
    for n in notes:
        print(f"note: {n}")
    if regressions:
        print(f"{len(regressions)} protocol-cost regression(s):",
              file=sys.stderr)
        for r in regressions:
            print(f"  REGRESSION {r}", file=sys.stderr)
        return 1
    if args.baseline is not None:
        print(f"no protocol-cost regressions "
              f"({len(index_results(new))} table rows, "
              f"{len(index_batched(new))} batched rows, "
              f"{len(index_sharded(new))} sharded rows, "
              f"{len(index_serving(new))} serving rows, "
              f"{len(index_serving_storm(new))} serving_storm rows, "
              f"{len(index_aggregation(new))} aggregation rows, "
              f"{len(index_pattern(new))} pattern rows, "
              f"{len(index_mesh(new))} mesh rows, "
              f"{len(index_embedding(new))} embedding rows checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
