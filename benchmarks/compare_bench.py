"""Protocol-cost regression gate for the BENCH_queries.json trajectory.

Diffs a fresh ``BENCH_queries.json`` against a previous run's artifact (the
CI bench-smoke lane uploads one per PR). Protocol costs — communication
rounds and bits per (bench, name, n) configuration — are *deterministic*
functions of the protocol, so any increase is a real regression, not noise;
wall-times are reported but never gated (they jitter with the runner).

Exit status: 0 = no protocol-cost regressions, 1 = regression(s) found,
2 = the artifacts could not be loaded/compared.

Usage::

  PYTHONPATH=src python benchmarks/compare_bench.py NEW.json BASELINE.json
      [--allow-missing]   # dropped configs are reported but not fatal

New configurations (queries added since the baseline) are informational.
A configuration present in the baseline but missing from the fresh run is
treated as a regression unless ``--allow-missing`` is given — silently
dropping a bench row is how cost regressions hide.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

#: per-config protocol costs that must never increase (deterministic).
GATED_KEYS = ("rounds", "comm_bits")
#: deterministic cloud/user work — drift is surfaced but not fatal (a PR
#: may legitimately trade cloud work for communication).
INFO_KEYS = ("cloud_bits", "user_bits")


def _load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "bench_queries/v1":
        raise ValueError(f"{path}: unknown schema {doc.get('schema')!r}")
    return doc


def index_results(doc: dict) -> Dict[Tuple[str, str, int], dict]:
    return {(r["bench"], r["name"], r["n"]): r for r in doc["results"]}


def index_batched(doc: dict) -> Dict[Tuple[str, int, int], dict]:
    return {(r["name"], r["batch"], r["n"]): r for r in doc["batched"]}


def compare(new: dict, old: dict, *, allow_missing: bool = False
            ) -> Tuple[List[str], List[str]]:
    """-> (regressions, notes). Empty regressions == gate passes."""
    regressions: List[str] = []
    notes: List[str] = []

    def diff_rows(kind, new_idx, old_idx, gated, info=()):
        for key, old_row in old_idx.items():
            tag = f"{kind} {'/'.join(str(k) for k in key)}"
            new_row = new_idx.get(key)
            if new_row is None:
                msg = f"{tag}: config vanished from the fresh run"
                (notes if allow_missing else regressions).append(msg)
                continue
            for field in gated:
                if new_row[field] > old_row[field]:
                    regressions.append(
                        f"{tag}: {field} {old_row[field]} -> "
                        f"{new_row[field]} (+{new_row[field] - old_row[field]})")
            for field in info:
                if new_row[field] != old_row[field]:
                    notes.append(f"{tag}: {field} {old_row[field]} -> "
                                 f"{new_row[field]}")
        for key in new_idx.keys() - old_idx.keys():
            notes.append(f"{kind} {'/'.join(str(k) for k in key)}: "
                         f"new config (no baseline)")

    diff_rows("table", index_results(new), index_results(old),
              GATED_KEYS, INFO_KEYS)
    diff_rows("batched", index_batched(new), index_batched(old),
              GATED_KEYS)
    for key, row in index_batched(new).items():
        if not row.get("ledger_equal", False):
            regressions.append(
                f"batched {'/'.join(str(k) for k in key)}: "
                f"batch != sequential ledger (fusion broke cost identity)")
    return regressions, notes


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="fresh BENCH_queries.json")
    ap.add_argument("baseline", help="previous run's BENCH_queries.json")
    ap.add_argument("--allow-missing", action="store_true",
                    help="dropped configs are notes, not regressions")
    args = ap.parse_args(argv)
    try:
        new, old = _load(args.new), _load(args.baseline)
        regressions, notes = compare(new, old,
                                     allow_missing=args.allow_missing)
    except (OSError, ValueError, KeyError) as e:
        print(f"compare_bench: cannot compare: {e}", file=sys.stderr)
        return 2
    for n in notes:
        print(f"note: {n}")
    if regressions:
        print(f"{len(regressions)} protocol-cost regression(s):",
              file=sys.stderr)
        for r in regressions:
            print(f"  REGRESSION {r}", file=sys.stderr)
        return 1
    print(f"no protocol-cost regressions "
          f"({len(index_results(new))} table rows, "
          f"{len(index_batched(new))} batched rows checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
