"""Benchmarks reproducing Table 1: measured communication bits, rounds, and
cloud/user computational cost for every query class, at several relation
sizes, printed next to the paper's asymptotic claim.

Queries run through the unified ``repro.api.QueryClient`` (the client
delegates to the protocol implementations, so measured ledgers are identical
to the legacy free functions — asserted by tests/test_api.py). Strategies
are forced where a bench targets one paper row; ``bench_planner_auto``
reports what the cost-based planner picks; ``bench_batched_vs_sequential``
sweeps ``QueryClient.run_batch`` against the per-query loop and asserts
ledger equality while measuring the fusion speedup;
``bench_sharded_dataplane`` runs a mixed batch over ``ShardedRelation``
(S ∈ {1,2,4}) and asserts the dataplane acceptance shape: bit-identical
rows/ledgers, dispatch fan-out = steps × S over ceil(n/S)-tuple blocks,
zero added rounds; ``bench_multi_tenant_serving`` routes a mixed workload
over two relations through ONE multi-tenant ``QueryServer`` and asserts
it matches two solo single-relation servers bit for bit;
``bench_serving_storm`` floods one tenant at 10× a neighbour's rate and
asserts the neighbour's p95 stays flat vs solo (weighted fair quotas +
adaptive deadline steering) with a bit-identical transcript;
``bench_embedding`` sweeps the §3.2.1 oblivious embedding fast path (one
``EmbedLookup`` = one fused ``ss_matmul`` per shard against the
device-resident quantized table) and asserts the acceptance shape:
>= 5x tokens/sec over the per-call baseline at 256 tokens, S dispatches
per step, zero post-placement transfer, batched == sequential ledgers;
``bench_pattern`` sweeps the LIKE/prefix/suffix/substring engine —
counts and selects vs a cleartext oracle, ``explain()`` exact to the
measured ledger, wildcard-free LIKE == Eq bit-for-bit, and a mixed
pattern+equality batch equal to the sequential loop.

Each table function returns rows of
  (name, n, us_per_call, comm_bits, rounds, cloud_bits, user_bits, claim)

Run as a script to track the perf trajectory across PRs:

  PYTHONPATH=src python benchmarks/bench_queries.py --smoke \
      --out BENCH_queries.json

writes machine-readable per-config results (rounds, bits, wall-times and
the batched sweep) to ``BENCH_queries.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional, Sequence

import jax

from repro.api import Aggregate, Between, Contains, Count, DBStats, Join, \
    Like, Prefix, QueryClient, RangeCount, RangeSelect, Select, Suffix, \
    Eq, Padding, choose_select_strategy
from repro.core import outsource, Codec
from repro.data import synthetic_relation

CODEC = Codec(word_length=8)
W = 31  # field word bits
COLUMNS = ["EmployeeId", "FirstName", "LastName", "Salary", "Department"]


def _db(n, *, seed=0, skew=0.0, n_shares=20, numeric=False):
    rows = synthetic_relation(n, seed=seed, skew=skew)
    return rows, outsource(jax.random.PRNGKey(seed), rows,
                           column_names=COLUMNS, codec=CODEC,
                           n_shares=n_shares, degree=1,
                           numeric_columns={3: 14} if numeric else None)


def _timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def bench_count(sizes: Optional[Sequence[int]] = None) -> List[tuple]:
    """Table 1 row: 'Our solution §3.1' — O(1) comm, nw cloud, 1 round."""
    rows_out = []
    for n in (sizes or (32, 128, 512)):
        rows, db = _db(n, skew=0.3)
        client = QueryClient(db, key=1)
        res, us = _timed(client.count, "FirstName", "John")
        want = sum(1 for r in rows if r[1] == "John")
        assert res.count == want, (res.count, want)
        led = res.ledger
        rows_out.append(("count_3.1", n, us, led.communication_bits,
                         led.rounds, led.cloud_ops_bits, led.user_ops_bits,
                         "comm O(1), cloud nw, 1 round"))
    return rows_out


def bench_select_single(sizes: Optional[Sequence[int]] = None
                        ) -> List[tuple]:
    """Row 'Our §3.2.1': comm O(mw), cloud O(nmw), user O(mw), 1 round."""
    out = []
    for n in (sizes or (32, 128, 512)):
        rows = synthetic_relation(n - 1, seed=3)
        rows.append([f"E{99 + n}", "Zed", "Quine", "777", "HR"])
        db = outsource(jax.random.PRNGKey(3), rows, column_names=COLUMNS,
                       codec=CODEC, n_shares=20, degree=1)
        client = QueryClient(db, key=2)
        unique = "Zed"   # guaranteed single occurrence
        res, us = _timed(client.select, "FirstName", unique,
                         strategy="one_tuple")
        assert res.rows[0][1] == unique
        led = res.ledger
        out.append(("select_one_3.2.1", n, us, led.communication_bits,
                    led.rounds, led.cloud_ops_bits, led.user_ops_bits,
                    "comm O(mw), cloud O(nmw), user O(mw)"))
    return out


def bench_select_one_round(sizes: Optional[Sequence[int]] = None
                           ) -> List[tuple]:
    """Row 'Our §3.2.2 fetching tuples': comm O((n+m)ℓw), cloud O(ℓnmw)."""
    out = []
    for n in (sizes or (32, 128, 256)):
        rows, db = _db(n, seed=4, skew=0.2)
        client = QueryClient(db, key=3)
        res, us = _timed(client.select, "FirstName", "John",
                         strategy="one_round")
        assert res.addresses == [i for i, r in enumerate(rows)
                                 if r[1] == "John"]
        led = res.ledger
        out.append(("select_oneround_3.2.2", n, us, led.communication_bits,
                    led.rounds, led.cloud_ops_bits, led.user_ops_bits,
                    "comm O((n+m)lw), cloud O(lnmw), 1+1 rounds"))
    return out


def bench_select_tree(sizes: Optional[Sequence[int]] = None) -> List[tuple]:
    """Row 'Our §3.2.2 knowing addresses': rounds ≤ log_ℓ n + log₂ ℓ + 1."""
    import math
    out = []
    for n in (sizes or (64, 256)):
        rows, db = _db(n, seed=5, skew=0.15)
        client = QueryClient(db, key=4)
        res, us = _timed(client.select, "FirstName", "John", strategy="tree")
        led = res.ledger
        ell = max(len(res.addresses), 2)
        bound = (math.floor(math.log(n, ell)) + math.floor(math.log2(ell))
                 + 1 + 2)
        assert led.rounds <= bound, (led.rounds, bound)
        out.append(("select_tree_3.2.2", n, us, led.communication_bits,
                    led.rounds, led.cloud_ops_bits, led.user_ops_bits,
                    f"rounds<= {bound} (log_l n + log2 l + 1 [+2])"))
    return out


def bench_planner_auto() -> List[tuple]:
    """Planner sanity: one_round for small n, tree once c·n dominates."""
    out = []
    for n in (64, 1 << 20):
        stats = DBStats(n=n, m=5, c=20, w=CODEC.word_length,
                        a=CODEC.alphabet_size)
        est = choose_select_strategy(stats, ell=4)
        out.append((f"planner_auto_{est.strategy}", n, 0.0, est.bits,
                    est.rounds, 0, 0,
                    "planner: one_round small n -> tree large n"))
    assert out[0][0].endswith("one_round") and out[1][0].endswith("tree")
    return out


def bench_join(sizes: Optional[Sequence[int]] = None) -> List[tuple]:
    """Rows '§3.3': PK/FK join O(nmw) comm / O(n²mw) cloud; equijoin Thm 6."""
    out = []
    codec = Codec(word_length=6)
    for n in (sizes or (8, 16, 32)):
        X = [[f"a{i}", f"b{i}"] for i in range(n)]
        Y = [[f"b{i % (n // 2)}", f"c{i}"] for i in range(n)]
        dbX = outsource(jax.random.PRNGKey(5), X, column_names=["A", "B"],
                        codec=codec, n_shares=16)
        dbY = outsource(jax.random.PRNGKey(6), Y, column_names=["B", "C"],
                        codec=codec, n_shares=16)
        client = QueryClient(dbX, key=5)
        res, us = _timed(client.join, dbY, on=("B", "B"), kind="pkfk")
        assert len(res.rows) == n  # every child joins exactly one parent
        led = res.ledger
        out.append(("pkfk_join_3.3.1", n, us, led.communication_bits,
                    led.rounds, led.cloud_ops_bits, led.user_ops_bits,
                    "comm O(nmw), cloud O(n^2 mw), user O(nmw)"))
    X = [["a1", "b1"], ["a2", "b2"], ["a3", "b2"], ["a4", "b9"]]
    Y = [["b2", "c1"], ["b2", "c2"], ["b1", "c3"], ["b7", "c4"]]
    dbX = outsource(jax.random.PRNGKey(7), X, column_names=["A", "B"],
                    codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(8), Y, column_names=["B", "C"],
                    codec=codec, n_shares=16)
    client = QueryClient(dbX, key=9)
    res, us = _timed(client.join, dbY, on=("B", "B"), kind="equi")
    # b1 joins 1×1, b2 joins 2×2 -> 5 output tuples
    assert len(res.rows) == 5
    led = res.ledger
    out.append(("equijoin_3.3.2", 4, us, led.communication_bits, led.rounds,
                led.cloud_ops_bits, led.user_ops_bits,
                "rounds O(2k), comm O(2nwk + 2k l^2 mw)"))
    return out


def bench_range(sizes: Optional[Sequence[int]] = None) -> List[tuple]:
    """Row '§3.4': same order as count (Thm 7)."""
    out = []
    for n in (sizes or (16, 64)):
        rows, db = _db(n, seed=10, n_shares=34, numeric=True)
        client = QueryClient(db, key=11)
        lo, hi = 1000, 4000
        res, us = _timed(client.range_count, "Salary", lo, hi)
        want = sum(1 for r in rows if lo <= int(r[3]) <= hi)
        assert res.count == want, (res.count, want)
        led = res.ledger
        out.append(("range_count_3.4", n, us, led.communication_bits,
                    led.rounds, led.cloud_ops_bits, led.user_ops_bits,
                    "same order as count (Thm 7)"))
    return out


def bench_scaling_verification(sizes: Optional[Sequence[int]] = None
                               ) -> List[tuple]:
    """Empirical check of Table 1 *scaling*: count comm must be flat in n;
    cloud work linear in n."""
    out = []
    led_prev = None
    for n in (sizes or (64, 256, 1024)):
        rows, db = _db(n, seed=12)
        led = QueryClient(db, key=13).count("FirstName", "Eve").ledger
        if led_prev is not None:
            assert led.communication_bits == led_prev.communication_bits
            ratio = led.cloud_ops_bits / led_prev.cloud_ops_bits
            assert 3.5 < ratio < 4.5  # n grew 4x
        led_prev = led
        out.append(("count_scaling", n, 0.0, led.communication_bits,
                    led.rounds, led.cloud_ops_bits, led.user_ops_bits,
                    "comm flat in n; cloud linear in n"))
    return out


def _sweep_plans(name: str, db, plans, *, n: int, b: int,
                 out: List[dict]) -> None:
    """Run one batched-vs-sequential cell, assert ledger equality, record."""
    seq_client = QueryClient(db, key=21)
    t0 = time.time()
    seq = [seq_client.run(p) for p in plans]
    seq_us = (time.time() - t0) * 1e6
    bat_client = QueryClient(db, key=21)
    t0 = time.time()
    bat = bat_client.run_batch(plans)
    bat_us = (time.time() - t0) * 1e6
    assert all(a.rows == c.rows and a.count == c.count
               and a.ledger == c.ledger and a.strategy == c.strategy
               for a, c in zip(seq, bat)), "batch != sequential"
    out.append(dict(name=name, n=n, batch=b,
                    seq_us=round(seq_us), batch_us=round(bat_us),
                    speedup=round(seq_us / max(bat_us, 1e-9), 2),
                    rounds=bat[0].ledger.rounds,
                    comm_bits=bat[0].ledger.communication_bits,
                    ledger_equal=True))


def bench_batched_vs_sequential(*, batch_sizes: Sequence[int] = (8, 32),
                                n: int = 256) -> List[dict]:
    """The tentpole sweep: B same-relation queries via ``run_batch`` (every
    protocol round fused over the group) vs the same plans in a sequential
    loop — selections, ranges (one fused SS-SUB ripple per bit-round for
    the whole batch + the cross-group fetch) and PK/FK joins (match
    matrices riding the same fused fetch). Asserts per-query ledger
    equality — batching must be free in protocol cost — and reports the
    wall-time speedup.
    """
    out: List[dict] = []
    rows, db = _db(n, seed=6, skew=0.25, numeric=True)
    patterns = sorted({r[1] for r in rows})
    for strategy in ("one_round", "tree", "auto"):
        for b in batch_sizes:
            plans = [Select(Eq("FirstName", patterns[i % len(patterns)]),
                            strategy=("auto" if strategy == "auto"
                                      else strategy))
                     for i in range(b)]
            _sweep_plans(f"batched_select_{strategy}", db, plans,
                         n=n, b=b, out=out)
    for b in batch_sizes:
        plans = [RangeCount(Between("Salary", 500 + 100 * i, 5000),
                            reduce_every=2) if i % 2 == 0
                 else RangeSelect(Between("Salary", 600, 900 + 50 * i),
                                  reduce_every=2)
                 for i in range(b)]
        _sweep_plans("batched_range", db, plans, n=n, b=b, out=out)
    child = [[rows[i % n][0], f"t{i}"] for i in range(min(n, 16))]
    db_child = outsource(jax.random.PRNGKey(8), child,
                         column_names=["EmployeeId", "Task"], codec=CODEC,
                         n_shares=20, degree=1)
    for b in batch_sizes:
        plans = [Join(right=db_child, on=("EmployeeId", "EmployeeId"),
                      kind="pkfk") for _ in range(b)]
        _sweep_plans("batched_join_pkfk", db, plans, n=n, b=b, out=out)
    return out


def bench_sharded_dataplane(*, n: int = 128, batch: int = 8,
                            shard_counts: Sequence[int] = (1, 2, 4)
                            ) -> List[dict]:
    """The dataplane acceptance sweep: a mixed batch over ``ShardedRelation
    (S)`` must return bit-identical rows AND equal per-query ledgers to the
    S=1 path (sharding is execution policy, not protocol), while the
    per-shard dispatch count scales as S blocks of ceil(n/S) tuples — and
    the user↔cloud round count never moves.
    """
    import math

    from repro.api import ThreadedDispatcher

    rows, db = _db(n, seed=6, skew=0.25, numeric=True)
    patterns = sorted({r[1] for r in rows})
    child = [[rows[i % n][0], f"t{i}"] for i in range(8)]
    db_child = outsource(jax.random.PRNGKey(8), child,
                         column_names=["EmployeeId", "Task"], codec=CODEC,
                         n_shares=20, degree=1)
    plans = ([Select(Eq("FirstName", patterns[i % len(patterns)]),
                     strategy="one_round") for i in range(batch - 3)]
             + [RangeCount(Between("Salary", 500, 4000), reduce_every=2),
                RangeSelect(Between("Salary", 600, 1500), reduce_every=2),
                Join(right=db_child, on=("EmployeeId", "EmployeeId"),
                     kind="pkfk")])

    out: List[dict] = []
    base = None
    for s in shard_counts:
        client = QueryClient(db, key=33)
        pool = ThreadedDispatcher(max_workers=s) if s > 1 else None
        plane = client.attach(shards=s, dispatcher=pool)
        t0 = time.time()
        res = client.run_batch(plans)
        wall_us = (time.time() - t0) * 1e6
        if pool is not None:
            pool.close()
        if base is None:
            base = res
        ledger_equal = all(
            a.rows == b.rows and a.count == b.count
            and a.addresses == b.addresses and a.ledger == b.ledger
            for a, b in zip(base, res))
        assert ledger_equal, f"sharded S={s} != S=1 (rows or ledgers)"
        # every sharded cloud step fans out exactly n_shards dispatches of
        # ceil(n/S)-tuple blocks; rounds never move with S.
        assert plane.stats.dispatches == plane.stats.steps * plane.n_shards
        assert plane.max_shard_rows == math.ceil(n / plane.n_shards)
        assert res[0].ledger.rounds == base[0].ledger.rounds
        out.append(dict(name="sharded_batch", n=n, batch=len(plans),
                        shards=plane.n_shards,
                        dispatches=plane.stats.dispatches,
                        steps=plane.stats.steps,
                        shard_rows=plane.max_shard_rows,
                        wall_us=round(wall_us),
                        rounds=res[0].ledger.rounds,
                        comm_bits=res[0].ledger.communication_bits,
                        ledger_equal=ledger_equal))
    return out


def bench_multi_tenant_serving(*, n: int = 64, queries: int = 6
                               ) -> List[dict]:
    """The multi-tenant serving acceptance sweep: a mixed workload routed
    to ONE ``QueryServer`` over two attached relations (different shard
    counts, shared dispatcher pool) must return rows and ledgers
    bit-identical to running each relation on its own single-relation
    server — per-relation queues, key streams and batch groups make
    tenant transcripts independent of neighbour traffic.
    """
    from repro.launch.serve import QueryRequest, QueryServer

    rows_a, db_a = _db(n, seed=11, skew=0.25, numeric=True)
    rows_b, db_b = _db(max(8, n // 2), seed=12, skew=0.4)
    pats_a = sorted({r[1] for r in rows_a})
    pats_b = sorted({r[4] for r in rows_b})
    plans_a = [Select(Eq("FirstName", pats_a[i % len(pats_a)]),
                      strategy="one_round") for i in range(queries - 1)]
    plans_a.append(RangeCount(Between("Salary", 500, 4000),
                              reduce_every=2))
    plans_b = [Count(Eq("Department", pats_b[i % len(pats_b)]))
               for i in range(queries)]

    def solo(db, key, plans, shards):
        srv = QueryServer(db, key=key, shards=shards)
        reqs = srv.serve([QueryRequest(p) for p in plans])
        srv.close()
        assert all(r.error is None for r in reqs)
        return [r.result for r in reqs]

    solo_a = solo(db_a, 51, plans_a, shards=2)
    solo_b = solo(db_b, 52, plans_b, shards=3)

    server = QueryServer(pool_workers=4)
    server.attach("alpha", db_a, shards=2, key=51)
    server.attach("beta", db_b, shards=3, key=52)
    t0 = time.time()
    reqs_a = [server.submit(p, relation="alpha") for p in plans_a]
    reqs_b = [server.submit(p, relation="beta") for p in plans_b]
    while server.pending():
        server.pump()
    wall_us = (time.time() - t0) * 1e6
    server.close()

    multi = [r.result for r in reqs_a + reqs_b]
    ledger_equal = all(
        a.rows == b.rows and a.count == b.count
        and a.addresses == b.addresses and a.ledger == b.ledger
        for a, b in zip(solo_a + solo_b, multi))
    assert ledger_equal, "multi-tenant != solo servers (rows or ledgers)"
    snap = server.stats.snapshot()
    assert snap["relations"]["alpha"]["served"] == len(plans_a)
    assert snap["relations"]["beta"]["served"] == len(plans_b)
    return [dict(name="multi_tenant_mixed", n=n, relations=2,
                 queries=len(multi), wall_us=round(wall_us),
                 rounds=sum(r.ledger.rounds for r in multi),
                 comm_bits=sum(r.ledger.communication_bits for r in multi),
                 served_by_relation={k: v["served"]
                                     for k, v in snap["relations"].items()},
                 ledger_equal=ledger_equal)]


def bench_serving_storm(*, n: int = 48, duration_s: float = 2.5,
                        hot_ratio: int = 10) -> List[dict]:
    """The overload-isolation acceptance sweep: a hot tenant floods ONE
    ``QueryServer`` at ``hot_ratio``× a cold neighbour's request rate.

    Headline: the neighbour's p95 latency under the storm stays flat
    against a solo baseline (same relation, same plans, same rate, no
    neighbour) — weighted fair pool quotas bound the hot tenant's shard
    fan-out and adaptive deadline steering shrinks only ITS deadline
    (full closes) while the cold tenant's stays parked at the configured
    cap (deadline closes). ``p95_ratio`` (storm / solo) is gated in CI
    behind ``STORM_P95_TOLERANCE``; rounds/comm_bits of the neighbour's
    query are deterministic protocol costs and gate exactly. The
    neighbour's served results must be bit-identical (rows AND ledgers)
    to the solo run — per-relation key streams make tenant transcripts
    independent of neighbour traffic by construction.
    """
    import threading as _threading

    from repro.launch.serve import QueryRequest, QueryServer

    rows_h, db_h = _db(n, seed=21, skew=0.3)
    rows_c, db_c = _db(n, seed=22, skew=0.3)
    plan_h = Count(Eq("Department", rows_h[0][4]))
    plan_c = Count(Eq("Department", rows_c[0][4]))
    # the neighbour trickles (well under one max_batch per deadline, so
    # its batches close by deadline underfilled and its steered wait
    # parks at the cap); the hot tenant floods at hot_ratio x that rate
    # (fills max_batch before the deadline, so its steered wait dives).
    wait_ms, max_batch = 20.0, 4
    cold_period_s = 2 * wait_ms / 1e3
    hot_period_s = cold_period_s / hot_ratio
    # absorb one-time jit compilation before any latency is timed: every
    # batch fill 1..max_batch is a distinct stacked shape on the sharded
    # plane, so warm each once through a throwaway server.
    for db, key, plan in ((db_c, 172, plan_c), (db_h, 171, plan_h)):
        warm = QueryServer(db, key=key, shards=2, max_batch=max_batch)
        for fill in range(1, max_batch + 1):
            warm.serve([QueryRequest(plan) for _ in range(fill)])
        warm.close()

    def run(with_hot: bool):
        srv = QueryServer(pool_workers=4)
        # the neighbour being protected holds the larger DRR share of
        # the shared shard pool; the flooding tenant gets the remainder.
        srv.attach("cold", db_c, shards=2, key=72, max_batch=max_batch,
                   max_wait_ms=wait_ms, weight=2.0)
        if with_hot:
            srv.attach("hot", db_h, shards=2, key=71, max_batch=max_batch,
                       max_wait_ms=wait_ms, weight=1.0)
        cold_reqs, hot_reqs = [], []

        def submit(relation, plan, period_s, out, burst=1):
            # same mean rate regardless of burst: `burst` requests per
            # burst * period_s. The hot tenant storms in full-batch
            # bursts (the shape that closes batches by fill).
            t_end = time.time() + duration_s
            while time.time() < t_end:
                for _ in range(burst):
                    out.append(srv.submit(plan, relation=relation))
                time.sleep(burst * period_s)

        with srv:
            threads = [_threading.Thread(
                target=submit, args=("cold", plan_c, cold_period_s,
                                     cold_reqs))]
            if with_hot:
                threads.append(_threading.Thread(
                    target=submit, args=("hot", plan_h, hot_period_s,
                                         hot_reqs, max_batch)))
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for r in cold_reqs + hot_reqs:
                r.wait(timeout=120)
        assert all(r.error is None for r in cold_reqs + hot_reqs)
        snap = srv.stats.snapshot()
        srv.close()
        return cold_reqs, hot_reqs, snap

    def mean_wait(rel):
        traj = rel["wait_trajectory_ms"] or [rel["steered_wait_ms"]]
        return sum(traj) / len(traj)

    def attempt():
        solo_cold, _, solo_snap = run(with_hot=False)
        storm_cold, storm_hot, storm_snap = run(with_hot=True)

        # the neighbour's transcript is independent of the storm: the
        # shared prefix of its request stream must match the solo run
        # bit-for-bit.
        prefix = min(len(solo_cold), len(storm_cold))
        ledger_equal = all(
            a.result.count == b.result.count
            and a.result.ledger == b.result.ledger
            for a, b in zip(solo_cold[:prefix], storm_cold[:prefix]))
        assert ledger_equal, "storm perturbed the neighbour's transcript"

        solo_p95 = solo_snap["relations"]["cold"]["p95_latency_s"]
        storm_p95 = storm_snap["relations"]["cold"]["p95_latency_s"]
        hot_rel = storm_snap["relations"]["hot"]
        cold_rel = storm_snap["relations"]["cold"]
        led = storm_cold[0].result.ledger
        return dict(name="serving_storm", n=n, hot_ratio=hot_ratio,
                    cold_served=len(storm_cold),
                    hot_served=len(storm_hot),
                    solo_p95_us=round(solo_p95 * 1e6),
                    storm_p95_us=round(storm_p95 * 1e6),
                    p95_ratio=round(storm_p95 / max(solo_p95, 1e-9), 3),
                    hot_steered_wait_ms=round(mean_wait(hot_rel), 3),
                    cold_steered_wait_ms=round(mean_wait(cold_rel), 3),
                    steering_diverged=bool(mean_wait(hot_rel)
                                           < 0.5 * mean_wait(cold_rel)),
                    hot_closes=hot_rel["closes"],
                    cold_closes=cold_rel["closes"],
                    rounds=led.rounds, comm_bits=led.communication_bits,
                    ledger_equal=ledger_equal)

    # The neighbour serves only ~duration/cold_period requests, so its
    # p95 is within a couple of samples of the max — one scheduler or GC
    # hiccup on the host can blow a single run past the gate. Like the
    # mesh wall gate, grant timing (and timing only — transcripts assert
    # unconditionally) one retry and keep the better attempt.
    ceiling = float(os.environ.get("STORM_P95_TOLERANCE", "1.5"))
    best = None
    for _ in range(2):
        row = attempt()
        if best is None or (row["steering_diverged"], -row["p95_ratio"]) \
                > (best["steering_diverged"], -best["p95_ratio"]):
            best = row
        if best["p95_ratio"] <= ceiling and best["steering_diverged"]:
            break
    return [best]


def bench_aggregation(*, n: int = 64) -> List[dict]:
    """The private-analytics acceptance sweep: verified secret-shared
    SUM/AVG/MIN-MAX (OBSCURE-style) through ``run_batch``. Per op it
    records rounds and comm bits, asserts batched == sequential ledger
    equality AND a plaintext-oracle match, and prices verification by
    measuring the same plan verify-on vs verify-off (the overhead the
    planner promises: one round + c checksum elements per opened tensor).

    Salary is outsourced at 15 bits here: conditional MIN/MAX mask
    non-matching rows to the ±(2^(t-2)-1) sentinel, so values (≤ 7999)
    must fit one headroom bit below the column width.
    """
    import statistics

    rows = synthetic_relation(n, seed=14, skew=0.3)
    db = outsource(jax.random.PRNGKey(14), rows, column_names=COLUMNS,
                   codec=CODEC, n_shares=20, degree=1,
                   numeric_columns={3: 15})
    sal = [int(r[3]) for r in rows]
    johns = [s for s, r in zip(sal, rows) if r[1] == "John"]
    specs = [
        ("agg_sum", Aggregate("sum", "Salary"), sum(sal)),
        ("agg_sum_cond", Aggregate("sum", "Salary",
                                   where=Eq("FirstName", "John")),
         sum(johns)),
        ("agg_avg_cond", Aggregate("avg", "Salary",
                                   where=Eq("FirstName", "John")),
         statistics.mean(johns)),
        ("agg_min_cond", Aggregate("min", "Salary",
                                   where=Eq("FirstName", "John"),
                                   reduce_every=2), min(johns)),
        ("agg_max", Aggregate("max", "Salary", reduce_every=2), max(sal)),
    ]
    out: List[dict] = []
    plans = [p for _, p, _ in specs]
    seq = [QueryClient(db, key=41).run(p) for p in plans]
    t0 = time.time()
    bat = QueryClient(db, key=41).run_batch(plans)
    bat_us = (time.time() - t0) * 1e6
    for (name, plan, want), a, b in zip(specs, seq, bat):
        ledger_equal = (a.ledger == b.ledger and a.value == b.value)
        assert ledger_equal, f"{name}: batch != sequential"
        got = b.value
        assert (abs(got - want) < 1e-9), (name, got, want)
        ver = QueryClient(db, key=41).run(
            Aggregate(plan.op, plan.column, where=plan.where, verify=True,
                      reduce_every=plan.reduce_every))
        assert ver.value == a.value    # verification never moves the value
        out.append(dict(
            name=name, n=n, batch=len(plans),
            rounds=b.ledger.rounds, comm_bits=b.ledger.communication_bits,
            verify_rounds=ver.ledger.rounds - a.ledger.rounds,
            verify_comm_bits=(ver.ledger.communication_bits
                              - a.ledger.communication_bits),
            batch_us=round(bat_us), ledger_equal=ledger_equal))
    return out


def bench_mesh_dispatcher(*, n: int = 64, shards: int = 4) -> List[dict]:
    """The hardware-placement acceptance sweep: one query family at a time
    through a device-resident ``MeshDispatcher`` (shard_map SPMD reduce,
    donated share buffers) vs the host ``SerialDispatcher``. Per family it
    records the measured steady-state wall time (second batch: placement
    and compilation already paid) AND the HLO-predicted cost of the
    compiled on-device reduction programs — FLOPs, HBM bytes, collective
    bytes — so ``compare_bench.py`` can gate mesh speed regressions
    against the prediction-anchored baseline. Transcript identity with the
    serial path is asserted, and the transfer telemetry must stay at the
    one-time placement after the warm batch (device residency).
    """
    from repro.api import MeshDispatcher
    from repro.launch.mesh import make_dispatch_mesh

    rows, db = _db(n, seed=9, skew=0.25, numeric=True)
    patterns = sorted({r[1] for r in rows})
    child = [[rows[i % n][0], f"t{i}"] for i in range(8)]
    db_child = outsource(jax.random.PRNGKey(9), child,
                         column_names=["EmployeeId", "Task"], codec=CODEC,
                         n_shares=20, degree=1)
    families = [
        ("mesh_count", Count(Eq("FirstName", patterns[0]))),
        ("mesh_select", Select(Eq("FirstName", patterns[1 % len(patterns)]),
                               strategy="one_round")),
        ("mesh_range", RangeCount(Between("Salary", 500, 4000),
                                  reduce_every=2)),
        ("mesh_join", Join(right=db_child, on=("EmployeeId", "EmployeeId"),
                           kind="pkfk")),
        ("mesh_aggregate", Aggregate("sum", "Salary",
                                     where=Eq("FirstName", patterns[0]),
                                     verify=True)),
    ]
    mesh = make_dispatch_mesh()
    devices = int(mesh.shape["data"] * mesh.shape["model"])
    out: List[dict] = []
    for name, plan in families:
        serial = QueryClient(db, key=37)
        serial.attach(shards=shards)
        ref, serial_us = _timed(serial.run_batch, [plan])

        client = QueryClient(db, key=37)
        disp = MeshDispatcher(mesh)
        plane = client.attach(shards=shards, dispatcher=disp)
        got, _warm_us = _timed(client.run_batch, [plan])   # placement+compile
        placed = plane.stats.transfer_bytes
        _, wall_us = _timed(client.run_batch, [plan])      # steady state
        assert plane.stats.transfer_bytes == placed, \
            f"{name}: share buffers left the device after placement"

        ledger_equal = all(
            a.rows == b.rows and a.count == b.count and a.value == b.value
            and a.addresses == b.addresses and a.ledger == b.ledger
            for a, b in zip(ref, got))
        assert ledger_equal, f"{name}: mesh != serial (rows or ledgers)"
        cost = disp.predicted_cost()
        # families whose combine is a concat (range planes) compile no
        # on-device reduction — their predicted cost is legitimately zero
        out.append(dict(name=name, n=n, shards=shards, devices=devices,
                        wall_us=round(wall_us), serial_us=round(serial_us),
                        predicted_flops=int(cost["flops"]),
                        predicted_hbm_bytes=int(cost["hbm_bytes"]),
                        predicted_collective_bytes=int(
                            cost["collective_bytes"]),
                        programs=int(cost["programs"]),
                        placed_bytes=placed,
                        rounds=ref[0].ledger.rounds,
                        comm_bits=ref[0].ledger.communication_bits,
                        ledger_equal=ledger_equal))
    return out


def bench_embedding(*, vocab: int = 2048, d_model: int = 64,
                    n_tokens: int = 256,
                    shard_counts: Sequence[int] = (1, 2)) -> List[dict]:
    """The embedding fast path acceptance sweep (§3.2.1 at serving scale).

    One decode step = ONE ``EmbedLookup`` of ``n_tokens`` ids: all one-hots
    share in one jitted program and contract in one ``ss_matmul`` per shard
    against the device-resident quantized table. Per shard count it asserts
    the acceptance shape — exactly S dispatches per step (ONE fused
    ss_matmul each), zero post-placement transfer bytes (residency),
    batched == sequential ledgers, opened values exactly equal to the
    per-token ``private_lookup`` oracle — and measures tokens/sec against
    the per-call baseline (the pre-fast-path serving shape), which must
    trail by >= 5x at n_tokens >= 256.
    """
    import numpy as np

    from repro.api import EmbedLookup, MeshDispatcher
    from repro.models import private_embed as pe

    key = jax.random.PRNGKey(13)
    rng = np.random.default_rng(13)
    table = rng.uniform(-1.0, 1.0, (vocab, d_model)).astype(np.float32)
    table_sh = pe.setup_private_embed(jax.random.fold_in(key, 0), table,
                                      n_shares=4)
    tokens = tuple(int(t) for t in rng.integers(0, vocab, n_tokens))

    # per-call baseline: one eager private_lookup per token (warm first)
    pe.private_lookup(jax.random.fold_in(key, 1), table_sh,
                      jax.numpy.asarray([tokens[0]]))
    n_base = min(n_tokens, 32)
    t0 = time.time()
    for i in range(n_base):
        pe.private_lookup(jax.random.fold_in(key, 2 + i), table_sh,
                          jax.numpy.asarray([tokens[i]]))
    base_tps = n_base / max(time.time() - t0, 1e-9)

    # exactness oracle for a prefix of the batch
    oracle = np.concatenate([
        np.asarray(pe.private_lookup(jax.random.fold_in(key, 2 + i),
                                     table_sh,
                                     jax.numpy.asarray([tokens[i]])))
        for i in range(8)])

    out: List[dict] = []
    for s_count in shard_counts:
        client = QueryClient(key=11)
        plane = client.attach(pe.as_embed_relation(table_sh),
                              name="embeddings", shards=s_count,
                              dispatcher=MeshDispatcher())
        plan = EmbedLookup(tokens=tokens)
        base = client.run(plan, relation="embeddings")  # placement+compile
        placed = plane.stats.transfer_bytes
        d0 = plane.stats.dispatches
        got, wall_us = _timed(client.run, plan, relation="embeddings")
        assert plane.stats.transfer_bytes == placed, \
            f"S={s_count}: table shares left the device after placement"
        dispatches = plane.stats.dispatches - d0
        assert dispatches == s_count, \
            f"S={s_count}: {dispatches} dispatches per step (want one " \
            f"fused ss_matmul per shard)"
        assert np.array_equal(np.asarray(got.embeddings)[:8], oracle), \
            f"S={s_count}: batched path != per-token private_lookup"
        tps = n_tokens / max(wall_us / 1e6, 1e-9)
        speedup = tps / base_tps
        if n_tokens >= 256:
            assert speedup >= 5.0, \
                f"S={s_count}: batched path only {speedup:.1f}x over the " \
                f"per-call baseline (acceptance floor 5x)"

        # batched == sequential ledgers (two half-step jobs vs run_batch)
        halves = [EmbedLookup(tokens=tokens[:n_tokens // 2]),
                  EmbedLookup(tokens=tokens[n_tokens // 2:])]
        seq_client = QueryClient(key=11)
        seq_client.attach(pe.as_embed_relation(table_sh), name="embeddings",
                          shards=s_count, dispatcher=MeshDispatcher())
        seq = [seq_client.run(p, relation="embeddings") for p in halves]
        bat_client = QueryClient(key=11)
        bat_client.attach(pe.as_embed_relation(table_sh), name="embeddings",
                          shards=s_count, dispatcher=MeshDispatcher())
        bat = bat_client.run_batch(halves, relation="embeddings")
        ledger_equal = all(
            a.ledger == b.ledger
            and np.array_equal(np.asarray(a.embeddings),
                               np.asarray(b.embeddings))
            for a, b in zip(seq, bat))
        assert ledger_equal, f"S={s_count}: batched != sequential"

        # OBSCURE-style verification overhead (value must not move)
        ver = client.run(EmbedLookup(tokens=tokens, verify=True),
                         relation="embeddings")
        assert np.array_equal(np.asarray(ver.embeddings),
                              np.asarray(got.embeddings))

        out.append(dict(
            name=f"embed_s{s_count}", vocab=vocab, d_model=d_model,
            n_tokens=n_tokens, shards=s_count,
            tokens_per_sec=round(tps, 1),
            baseline_tokens_per_sec=round(base_tps, 1),
            speedup=round(speedup, 2),
            dispatches_per_step=int(dispatches),
            per_token_bits=round(base.ledger.communication_bits / n_tokens),
            rounds=base.ledger.rounds,
            comm_bits=base.ledger.communication_bits,
            verify_rounds=ver.ledger.rounds - base.ledger.rounds,
            verify_comm_bits=(ver.ledger.communication_bits
                              - base.ledger.communication_bits),
            placed_bytes=placed, ledger_equal=ledger_equal))
    return out


def bench_pattern(*, n: int = 64, batch: int = 10) -> List[dict]:
    """The pattern-engine acceptance sweep: LIKE / prefix / suffix /
    substring predicates riding the fused round engine. Per predicate
    kind it runs the count (plus one one-round select) against a
    cleartext oracle and asserts the planner's ``explain()`` estimate
    equals the measured ledger bit-for-bit (``explain_exact`` — the
    pattern cost model shares its atoms with the round engine's
    charger, so any drift is a bug, not noise); a wildcard-free LIKE
    must price AND measure exactly as the Eq path (``eq_parity``); and
    a mixed pattern+equality batch through ``run_batch`` must equal the
    sequential loop per-query (``ledger_equal``) while measuring the
    fusion speedup.
    """
    rows, db = _db(n, seed=15, skew=0.25)
    names = [r[1] for r in rows]
    out: List[dict] = []
    counts = [
        ("pattern_count_like_prefix", Like("FirstName", "Jo%"),
         sum(w.startswith("Jo") for w in names)),
        ("pattern_count_prefix", Prefix("FirstName", "N"),
         sum(w.startswith("N") for w in names)),
        ("pattern_count_suffix", Suffix("FirstName", "a"),
         sum(w.endswith("a") for w in names)),
        ("pattern_count_contains", Contains("FirstName", "an"),
         sum("an" in w for w in names)),
        ("pattern_count_like_wild", Like("FirstName", "_o%"),
         sum(len(w) >= 2 and w[1] == "o" for w in names)),
    ]
    for name, pred, want in counts:
        client = QueryClient(db, key=61)
        plan = Count(pred)
        est = client.explain(plan)
        res, us = _timed(client.run, plan)
        assert res.count == want, (name, res.count, want)
        led = res.ledger
        explain_exact = (est.bits == led.communication_bits
                         and est.rounds == led.rounds)
        assert explain_exact, (name, est, led)
        out.append(dict(name=name, n=n, us_per_call=round(us),
                        rounds=led.rounds,
                        comm_bits=led.communication_bits,
                        explain_exact=explain_exact))

    client = QueryClient(db, key=62)
    plan = Select(Contains("FirstName", "an"), strategy="one_round")
    est = client.explain([plan])
    res, us = _timed(client.run, plan)
    want_rows = sorted(tuple(r) for r in rows if "an" in r[1])
    assert sorted(tuple(r) for r in res.rows) == want_rows
    led = res.ledger
    explain_exact = (est.bits == led.communication_bits
                     and est.rounds == led.rounds)
    assert explain_exact, (est, led)
    out.append(dict(name="pattern_select_one_round", n=n,
                    us_per_call=round(us), rounds=led.rounds,
                    comm_bits=led.communication_bits,
                    explain_exact=explain_exact))

    # wildcard-free LIKE lowers to the exact-match path: same count,
    # same ledger, under the same key stream
    like = QueryClient(db, key=63).run(Count(Like("FirstName", "John")))
    eq = QueryClient(db, key=63).run(Count(Eq("FirstName", "John")))
    eq_parity = (like.count == eq.count and like.ledger == eq.ledger)
    assert eq_parity, "wildcard-free LIKE diverged from the Eq path"
    out.append(dict(name="pattern_like_eq_parity", n=n,
                    rounds=like.ledger.rounds,
                    comm_bits=like.ledger.communication_bits,
                    eq_parity=eq_parity))

    preds = [Like("FirstName", "Jo%"), Suffix("FirstName", "a"),
             Contains("FirstName", "an"), Eq("FirstName", "John")]
    plans = [Count(preds[i % len(preds)]) if i % 2 == 0
             else Select(preds[i % len(preds)], strategy="one_round")
             for i in range(batch)]
    _sweep_plans("pattern_mixed_batch", db, plans, n=n, b=batch, out=out)
    return out


ALL = [bench_count, bench_select_single, bench_select_one_round,
       bench_select_tree, bench_planner_auto, bench_join, bench_range,
       bench_scaling_verification]

# tiny per-section configs for the CI bench-smoke lane (keeps the 4x ratio
# bench_scaling_verification asserts on)
SMOKE_SIZES = {
    "bench_count": (32,), "bench_select_single": (32,),
    "bench_select_one_round": (32,), "bench_select_tree": (64,),
    "bench_join": (8,), "bench_range": (16,),
    "bench_scaling_verification": (16, 64),
}


def collect(*, smoke: bool = False) -> dict:
    """Run every section and return the machine-readable result document."""
    results = []
    for fn in ALL:
        kw = {}
        if smoke and fn.__name__ in SMOKE_SIZES:
            kw["sizes"] = SMOKE_SIZES[fn.__name__]
        for row in fn(**kw):
            name, size, us, comm, rounds, cloud, user, claim = row
            results.append(dict(bench=fn.__name__, name=name, n=size,
                                us_per_call=round(us),
                                comm_bits=comm, rounds=rounds,
                                cloud_bits=cloud, user_bits=user,
                                paper_claim=claim))
    batched = bench_batched_vs_sequential(
        batch_sizes=(4, 16) if smoke else (8, 32),
        n=64 if smoke else 256)
    sharded = bench_sharded_dataplane(n=64 if smoke else 128,
                                      batch=6 if smoke else 8)
    serving = bench_multi_tenant_serving(n=32 if smoke else 64,
                                         queries=4 if smoke else 6)
    serving_storm = bench_serving_storm(n=32 if smoke else 48,
                                        duration_s=1.5 if smoke else 2.5)
    aggregation = bench_aggregation(n=32 if smoke else 64)
    pattern = bench_pattern(n=32 if smoke else 64,
                            batch=6 if smoke else 10)
    mesh = bench_mesh_dispatcher(n=32 if smoke else 64,
                                 shards=2 if smoke else 4)
    # acceptance needs batch×seq >= 256 tokens even in smoke; smoke shrinks
    # the table (vocab × d_model), not the token batch
    embedding = bench_embedding(vocab=512 if smoke else 2048,
                                d_model=32 if smoke else 64,
                                n_tokens=256,
                                shard_counts=(1, 2) if smoke else (1, 2, 4))
    return dict(schema="bench_queries/v1", smoke=smoke,
                results=results, batched=batched, sharded=sharded,
                serving=serving, serving_storm=serving_storm,
                aggregation=aggregation, pattern=pattern, mesh=mesh,
                embedding=embedding)


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs (CI bench-smoke lane)")
    ap.add_argument("--out", default="BENCH_queries.json",
                    help="where to write the JSON document")
    args = ap.parse_args(argv)
    doc = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    n_res, n_bat = len(doc["results"]), len(doc["batched"])
    print(f"wrote {args.out}: {n_res} table rows, {n_bat} batched-sweep "
          f"rows", file=sys.stderr)
    for b in doc["batched"]:
        print(f"  {b['name']} B={b['batch']} n={b['n']}: "
              f"{b['seq_us']}us -> {b['batch_us']}us "
              f"({b['speedup']}x)", file=sys.stderr)
    for s in doc["sharded"]:
        print(f"  {s['name']} S={s['shards']} n={s['n']}: "
              f"{s['dispatches']} dispatches over {s['steps']} steps, "
              f"ceil(n/S)={s['shard_rows']} rows/shard, "
              f"rounds={s['rounds']} (ledger_equal={s['ledger_equal']})",
              file=sys.stderr)
    for s in doc["serving"]:
        print(f"  {s['name']} relations={s['relations']} n={s['n']}: "
              f"{s['queries']} queries served by one scheduler "
              f"{s['served_by_relation']} "
              f"(ledger_equal={s['ledger_equal']})", file=sys.stderr)
    for s in doc["serving_storm"]:
        print(f"  {s['name']} hot_ratio={s['hot_ratio']} n={s['n']}: "
              f"neighbour p95 {s['storm_p95_us']}us vs solo "
              f"{s['solo_p95_us']}us (ratio {s['p95_ratio']}), steered "
              f"wait hot {s['hot_steered_wait_ms']}ms vs cold "
              f"{s['cold_steered_wait_ms']}ms "
              f"(diverged={s['steering_diverged']}, "
              f"ledger_equal={s['ledger_equal']})", file=sys.stderr)
    for a in doc["aggregation"]:
        print(f"  {a['name']} n={a['n']}: rounds={a['rounds']} "
              f"comm={a['comm_bits']}b, verify +{a['verify_rounds']}r "
              f"+{a['verify_comm_bits']}b "
              f"(ledger_equal={a['ledger_equal']})", file=sys.stderr)
    for p in doc["pattern"]:
        extra = (f"speedup={p['speedup']}x "
                 f"(ledger_equal={p['ledger_equal']})" if "speedup" in p
                 else f"explain_exact={p.get('explain_exact', '-')} "
                      f"eq_parity={p.get('eq_parity', '-')}")
        print(f"  {p['name']} n={p['n']}: rounds={p['rounds']} "
              f"comm={p['comm_bits']}b {extra}", file=sys.stderr)
    for m in doc["mesh"]:
        print(f"  {m['name']} S={m['shards']} devices={m['devices']} "
              f"n={m['n']}: {m['wall_us']}us (serial {m['serial_us']}us), "
              f"predicted {m['predicted_flops']} flops / "
              f"{m['predicted_hbm_bytes']} hbm B / "
              f"{m['predicted_collective_bytes']} coll B "
              f"(ledger_equal={m['ledger_equal']})", file=sys.stderr)
    for e in doc["embedding"]:
        print(f"  {e['name']} V={e['vocab']} D={e['d_model']} "
              f"tok={e['n_tokens']}: {e['tokens_per_sec']} tok/s "
              f"({e['speedup']}x over per-call "
              f"{e['baseline_tokens_per_sec']} tok/s), "
              f"{e['dispatches_per_step']} dispatch/step, "
              f"{e['per_token_bits']} bits/tok "
              f"(ledger_equal={e['ledger_equal']})", file=sys.stderr)


if __name__ == "__main__":
    main()
