"""Render the §Roofline markdown table from dryrun_results.json and splice
it into EXPERIMENTS.md (idempotent — replaces everything after the
ROOFLINE_TABLE marker)."""
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
MARKER = "<!-- ROOFLINE_TABLE -->"


def render(results: dict) -> str:
    lines = [
        "| arch | shape | mesh | bottleneck | t_compute | t_memory | "
        "t_collective | useful | mem/dev GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for k, v in sorted(results.items()):
        mesh = "256" if "256" in v["mesh"] else "512"
        if v["status"] != "ok":
            if "skipped" in v["status"]:
                lines.append(
                    f"| {v['arch']} | {v['shape']} | {mesh} | *skip:"
                    f" full-quadratic attn @500k* | – | – | – | – | – |")
            continue
        ur = v.get("useful_ratio")
        mem = v.get("peak_memory_per_device")
        lines.append(
            f"| {v['arch']} | {v['shape']} | {mesh} | {v['bottleneck']} | "
            f"{v['t_compute']:.2e} | {v['t_memory']:.2e} | "
            f"{v['t_collective']:.2e} | "
            f"{('%.3f' % ur) if ur is not None else '–'} | "
            f"{(mem or 0)/2**30:.2f} |")
    return "\n".join(lines) + "\n"


def main():
    src = os.path.join(ROOT, "dryrun_results.json")
    try:
        with open(src) as f:
            results = json.load(f)
    except FileNotFoundError:
        sys.exit(
            f"roofline_table: {src} not found — generate it with "
            f"`PYTHONPATH=src python -m repro.launch.dryrun` first. (For measured query "
            f"costs — HLO-predicted FLOPs/HBM/collective bytes of the "
            f"device-resident dispatcher — run `PYTHONPATH=src python "
            f"benchmarks/bench_queries.py` and read the `mesh` section "
            f"of BENCH_queries.json instead.)")
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        doc = f.read()
    head = doc.split(MARKER)[0]
    with open(path, "w") as f:
        f.write(head + MARKER + "\n\n" + render(results))
    ok = sum(1 for v in results.values() if v["status"] == "ok")
    print(f"table rendered: {ok} ok cells / {len(results)}")


if __name__ == "__main__":
    main()
