"""Trend tables for the BENCH_history.json protocol-cost time series.

``compare_bench.py --append-history`` chains every CI run's gated protocol
costs (rounds, comm_bits per configuration) onto a ``bench_history/v1``
document; this tool closes the loop by rendering that series as a
per-config trend table — one row per (section, configuration, metric),
the value at every recorded run, and a verdict:

  =          no change from the previous recorded run
  improved   the latest run is cheaper than the first (all-time progress)
  REGRESSED  the latest run is costlier than the PREVIOUS recorded run —
             the pairwise gate should have caught it; surfaced here in
             case a baseline was skipped (expired artifact, first run, …)

The gate compares only the last step, deliberately: a cost increase that
slips past a missing pairwise baseline fails the lane ONCE (on the run
that introduced it), then the series carries the new level and recovers —
an all-time-minimum gate would fail every future run with no way out
short of deleting the history.

Exit status: 0 = no regressed trends, 1 = at least one metric got worse
on the latest step, 2 = the history could not be loaded/validated. The CI
bench-smoke lane runs this right after chaining the history.

Usage::

  PYTHONPATH=src python benchmarks/plot_history.py BENCH_history.json
      [--section table|batched|sharded|serving|aggregation|pattern|mesh|embedding]
                                           # default: all sections
      [--metric rounds|comm_bits]          # default: both gated metrics
      [--format table|tsv]                 # tsv for spreadsheet import
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import compare_bench  # noqa: E402  (sibling module, shares the schema)

SECTIONS = ("table", "batched", "sharded", "serving", "serving_storm",
            "aggregation", "pattern", "mesh", "embedding")

#: per-run keys that are metadata, not cost sections.
_META_KEYS = ("label", "smoke")


def _section(run: dict, section: str) -> dict:
    """A run's cost mapping for ``section`` — {} unless it is a dict.

    Histories are append-only across PRs, so entries written by a newer
    ``compare_bench.py`` may carry sections (or experimental non-dict
    payloads) this tool does not know; those must degrade to "absent",
    never to a crash.
    """
    value = run.get(section)
    return value if isinstance(value, dict) else {}


def unknown_sections(history: dict) -> List[str]:
    """Section names present in some run but unknown to this tool."""
    return sorted({key for run in history["runs"] for key in run
                   if key not in SECTIONS and key not in _META_KEYS})


def trend_rows(history: dict, *, sections: Sequence[str] = SECTIONS,
               metrics: Sequence[str] = compare_bench.GATED_KEYS
               ) -> List[dict]:
    """-> one row per (section, config, metric) with the value series.

    A config absent from some runs (added or dropped mid-series) carries
    ``None`` at those positions; the verdict only compares recorded
    values. Rows come back sorted for stable output.
    """
    runs = history["runs"]
    rows: List[dict] = []
    for section in sections:
        configs = sorted({cfg for run in runs
                          for cfg in _section(run, section)})
        for cfg in configs:
            for metric in metrics:
                series: List[Optional[int]] = [
                    _section(run, section).get(cfg, {}).get(metric)
                    for run in runs]
                seen = [v for v in series if v is not None]
                if not seen:
                    continue
                if len(seen) >= 2 and seen[-1] > seen[-2]:
                    verdict = "REGRESSED"       # got worse THIS step
                elif seen[-1] < seen[0]:
                    verdict = "improved"
                else:
                    verdict = "="
                rows.append(dict(section=section, config=cfg,
                                 metric=metric, series=series,
                                 verdict=verdict))
    return rows


def format_trends(history: dict, rows: List[dict], *,
                  fmt: str = "table") -> str:
    labels = [run["label"] for run in history["runs"]]
    cells = [["section", "config", "metric", *labels, "trend"]]
    for r in rows:
        cells.append([r["section"], r["config"], r["metric"],
                      *["-" if v is None else str(v) for v in r["series"]],
                      r["verdict"]])
    if fmt == "tsv":
        return "\n".join("\t".join(row) for row in cells)
    widths = [max(len(row[i]) for row in cells)
              for i in range(len(cells[0]))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
             for row in cells]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("history", help="BENCH_history.json "
                                    "(bench_history/v1)")
    ap.add_argument("--section", choices=SECTIONS, default=None,
                    metavar="SECTION",
                    help="limit to one section (default: all)")
    ap.add_argument("--metric", choices=compare_bench.GATED_KEYS,
                    default=None,
                    help="limit to one gated metric (default: both)")
    ap.add_argument("--format", choices=("table", "tsv"), default="table")
    args = ap.parse_args(argv)
    try:
        with open(args.history) as f:
            history = json.load(f)
        compare_bench.validate_history(history)
        if not history["runs"]:
            raise ValueError("history has no runs to plot")
        for section in unknown_sections(history):
            # entries appended by a newer compare_bench — skip, don't fail.
            print(f"note: skipping unknown history section "
                  f"{section!r} (written by a newer tool?)",
                  file=sys.stderr)
        rows = trend_rows(
            history,
            sections=(args.section,) if args.section else SECTIONS,
            metrics=((args.metric,) if args.metric
                     else compare_bench.GATED_KEYS))
    except (OSError, ValueError, KeyError) as e:
        print(f"plot_history: cannot render: {e}", file=sys.stderr)
        return 2
    print(format_trends(history, rows, fmt=args.format))
    regressed = [r for r in rows if r["verdict"] == "REGRESSED"]
    if regressed:
        print(f"{len(regressed)} cost trend(s) REGRESSED across "
              f"{len(history['runs'])} run(s)", file=sys.stderr)
        return 1
    print(f"{len(rows)} cost trend(s) over {len(history['runs'])} run(s): "
          f"no regressions", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
