"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference paths for the
paper's two hot-spots, plus private-embed lookup throughput.

On CPU the interpret-mode Pallas numbers are NOT hardware-representative
(the TPU projection lives in EXPERIMENTS.md §Roofline); what this bench
establishes is (a) exact agreement, (b) the jnp oracle's scaling, which the
roofline model consumes.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)
P = 2**31 - 1


def _rand(shape):
    return jnp.asarray(RNG.integers(0, P, size=shape, dtype=np.uint32))


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.time() - t0) / reps * 1e6


def bench_ss_matmul() -> List[tuple]:
    out = []
    for m, k, n in ((128, 128, 128), (256, 512, 256)):
        a, b = _rand((m, k)), _rand((k, n))
        ref_out, us_ref = _time(lambda a, b: field.matmul(a, b), a, b)
        macs = m * k * n
        out.append(("ss_matmul_jnp", f"{m}x{k}x{n}", us_ref,
                    macs, 0, 0, 0, f"{macs/us_ref:.0f} modMAC/us"))
    a, b = _rand((128, 128)), _rand((128, 128))
    got, us_p = _time(ops.ss_matmul, a, b)
    want = ref.ss_matmul(a, b)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    out.append(("ss_matmul_pallas_interp", "128x128x128", us_p,
                128**3, 0, 0, 0, "exact vs oracle"))
    return out


def bench_ss_matmul_modes() -> List[tuple]:
    """Both interpret modes of the matmul kernel (satellite of the embedding
    fast path): ``interpret=None`` auto-detects the platform (compiled on a
    real TPU, interpret elsewhere) and ``interpret=True`` forces the
    interpreter — the two must agree exactly with the jnp oracle. Also
    covers the tall-skinny tiling (small M = tokens, huge K = vocab), the
    routed shape the embedding contraction dispatches."""
    from repro.kernels.ss_matmul import (is_tall_skinny, ss_matmul_pallas,
                                         ss_matmul_tall_pallas)
    out = []
    a, b = _rand((64, 128)), _rand((128, 64))
    want = ref.ss_matmul(a, b)
    got_auto, us_auto = _time(
        lambda x, y: ss_matmul_pallas(x, y), a, b)          # interpret=None
    got_forced, us_forced = _time(
        lambda x, y: ss_matmul_pallas(x, y, interpret=True), a, b)
    assert np.array_equal(np.asarray(got_auto), np.asarray(want))
    assert np.array_equal(np.asarray(got_forced), np.asarray(want))
    backend = jax.default_backend()
    out.append(("ss_matmul_interp_auto", f"64x128x64 [{backend}]", us_auto,
                64 * 128 * 64, 0, 0, 0, "exact vs oracle"))
    out.append(("ss_matmul_interp_forced", "64x128x64", us_forced,
                64 * 128 * 64, 0, 0, 0, "exact vs oracle"))
    m, k, n = 32, 2048, 64                       # the embedding shape class
    assert is_tall_skinny(m, k, n)
    a, b = _rand((m, k)), _rand((k, n))
    want = ref.ss_matmul(a, b)
    got_tall, us_tall = _time(
        lambda x, y: ss_matmul_tall_pallas(x, y), a, b)
    assert np.array_equal(np.asarray(got_tall), np.asarray(want))
    out.append(("ss_matmul_tall_pallas", f"{m}x{k}x{n}", us_tall,
                m * k * n, 0, 0, 0, "exact vs oracle (tall-skinny tiles)"))
    return out


def bench_share_onehot() -> List[tuple]:
    """Fused one-hot share generation vs the jnp reference program — the
    two halves of ``share_tokens``'s backend seam must be bit-identical
    given the same per-token coefficients."""
    from repro.core.queries.embed import share_tokens, token_coeffs
    from repro.kernels.ss_matmul import share_onehot_pallas
    out = []
    for m, v in ((64, 512), (256, 2048)):
        key = jax.random.PRNGKey(3)
        toks = jnp.asarray(RNG.integers(0, v, size=(m,)), jnp.int32)
        a1 = token_coeffs(key, toks, vocab=v)
        want = share_tokens(key, toks, vocab=v, n_shares=4).values
        got, us = _time(lambda t, a: share_onehot_pallas(t, a, n_shares=4,
                                                         interpret=True),
                        toks, a1)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        out.append(("share_onehot_pallas", f"M={m},V={v}", us, 4 * m * v,
                    0, 0, 0, "bit-identical vs jnp share program"))
    return out


def bench_aa_match() -> List[tuple]:
    out = []
    for n in (256, 1024):
        col, pat = _rand((n, 8, 64)), _rand((8, 64))
        got, us = _time(ops.aa_match, col, pat)
        want = ref.aa_match(col, pat)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        out.append(("aa_match_pallas_interp", n, us, n * 8 * 64, 0, 0, 0,
                    "exact vs oracle"))
        _, us_j = _time(lambda c, p: ref.aa_match(c, p), col, pat)
        out.append(("aa_match_jnp", n, us_j, n * 8 * 64, 0, 0, 0, ""))
    return out


def bench_private_embed() -> List[tuple]:
    from repro.models.private_embed import (setup_private_embed,
                                            private_lookup)
    out = []
    for v, d in ((512, 64), (2048, 128)):
        emb = jnp.asarray(RNG.normal(size=(v, d)), jnp.float32) * 0.02
        sh = setup_private_embed(jax.random.PRNGKey(0), emb, n_shares=4)
        toks = jnp.asarray(RNG.integers(0, v, size=(16,)), jnp.int32)
        got, us = _time(lambda t: private_lookup(jax.random.PRNGKey(1), sh,
                                                 t), toks)
        err = np.abs(np.asarray(got) - np.asarray(emb)[np.asarray(toks)])
        assert err.max() < 1.0 / 4096 + 1e-6
        out.append(("private_embed_lookup", f"V={v},d={d}", us, 16 * v * d,
                    0, 0, 0, "max err < 2^-12 (quantization only)"))
    return out


ALL = [bench_ss_matmul, bench_ss_matmul_modes, bench_share_onehot,
       bench_aa_match, bench_private_embed]
