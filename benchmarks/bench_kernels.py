"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference paths for the
paper's two hot-spots, plus private-embed lookup throughput.

On CPU the interpret-mode Pallas numbers are NOT hardware-representative
(the TPU projection lives in EXPERIMENTS.md §Roofline); what this bench
establishes is (a) exact agreement, (b) the jnp oracle's scaling, which the
roofline model consumes.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)
P = 2**31 - 1


def _rand(shape):
    return jnp.asarray(RNG.integers(0, P, size=shape, dtype=np.uint32))


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.time() - t0) / reps * 1e6


def bench_ss_matmul() -> List[tuple]:
    out = []
    for m, k, n in ((128, 128, 128), (256, 512, 256)):
        a, b = _rand((m, k)), _rand((k, n))
        ref_out, us_ref = _time(lambda a, b: field.matmul(a, b), a, b)
        macs = m * k * n
        out.append(("ss_matmul_jnp", f"{m}x{k}x{n}", us_ref,
                    macs, 0, 0, 0, f"{macs/us_ref:.0f} modMAC/us"))
    a, b = _rand((128, 128)), _rand((128, 128))
    got, us_p = _time(ops.ss_matmul, a, b)
    want = ref.ss_matmul(a, b)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    out.append(("ss_matmul_pallas_interp", "128x128x128", us_p,
                128**3, 0, 0, 0, "exact vs oracle"))
    return out


def bench_aa_match() -> List[tuple]:
    out = []
    for n in (256, 1024):
        col, pat = _rand((n, 8, 64)), _rand((8, 64))
        got, us = _time(ops.aa_match, col, pat)
        want = ref.aa_match(col, pat)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        out.append(("aa_match_pallas_interp", n, us, n * 8 * 64, 0, 0, 0,
                    "exact vs oracle"))
        _, us_j = _time(lambda c, p: ref.aa_match(c, p), col, pat)
        out.append(("aa_match_jnp", n, us_j, n * 8 * 64, 0, 0, 0, ""))
    return out


def bench_private_embed() -> List[tuple]:
    from repro.models.private_embed import (setup_private_embed,
                                            private_lookup)
    out = []
    for v, d in ((512, 64), (2048, 128)):
        emb = jnp.asarray(RNG.normal(size=(v, d)), jnp.float32) * 0.02
        sh = setup_private_embed(jax.random.PRNGKey(0), emb, n_shares=4)
        toks = jnp.asarray(RNG.integers(0, v, size=(16,)), jnp.int32)
        got, us = _time(lambda t: private_lookup(jax.random.PRNGKey(1), sh,
                                                 t), toks)
        err = np.abs(np.asarray(got) - np.asarray(emb)[np.asarray(toks)])
        assert err.max() < 1.0 / 4096 + 1e-6
        out.append(("private_embed_lookup", f"V={v},d={d}", us, 16 * v * d,
                    0, 0, 0, "max err < 2^-12 (quantization only)"))
    return out


ALL = [bench_ss_matmul, bench_aa_match, bench_private_embed]
