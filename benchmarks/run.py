"""Benchmark harness — one section per paper table/claim.

Prints ``name,size,us_per_call,comm_bits,rounds,cloud_bits,user_bits,claim``
CSV rows. Table 1 rows (count/selection/join/range) are measured on the real
implementation via the cost ledger; kernel benches validate the Pallas
hot-spots; the roofline section summarizes dryrun_results.json if present.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import os
import sys


def main() -> None:
    from benchmarks import bench_queries, bench_kernels

    print("name,size,us_per_call,comm_bits,rounds,cloud_bits,user_bits,"
          "paper_claim")
    failures = 0
    for fn in bench_queries.ALL + bench_kernels.ALL:
        try:
            for row in fn():
                name, size, us, comm, rounds, cloud, user, claim = row
                print(f"{name},{size},{us:.0f},{comm},{rounds},{cloud},"
                      f"{user},\"{claim}\"")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},ERROR,,,,,,\"{e}\"", file=sys.stderr)

    # roofline summary (from the dry-run artifact, if present)
    res_path = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.json")
    if os.path.exists(res_path):
        with open(res_path) as f:
            results = json.load(f)
        ok = [v for v in results.values() if v.get("status") == "ok"]
        print(f"# dryrun: {len(ok)} cells ok / {len(results)} total",
              file=sys.stderr)
        print("roofline_cell,mesh,bottleneck,t_compute_s,t_memory_s,"
              "t_collective_s,useful_flops_ratio")
        for v in sorted(ok, key=lambda v: (v["arch"], v["shape"],
                                           v["mesh"])):
            ur = v.get("useful_ratio")
            print(f"{v['arch']}|{v['shape']},{v['mesh']},{v['bottleneck']},"
                  f"{v['t_compute']:.3e},{v['t_memory']:.3e},"
                  f"{v['t_collective']:.3e},"
                  f"{ur if ur is None else round(ur, 4)}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
